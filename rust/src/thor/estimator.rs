//! Additive estimation (paper §3.4, eq. 4): parse the target model, look
//! up each group's family GP, predict at the group's channel features,
//! and sum:
//!
//! Ê_model = Ê_input(C₁) + Σ Ê_hidden(C_{i−1}, C_i) + Ê_output(C_{n−1})
//!
//! §Perf: queries are grouped **by family** and answered with one
//! `predict_batch` per family (ResNet-56's 55 groups collapse to a
//! handful of batched GP calls), with an optional [`EstimateCache`]
//! memoizing `(family, features) → (mean, var)` across calls — the
//! pruning candidate sweep re-queries the same few families at
//! overlapping widths thousands of times.  Both paths are bit-identical
//! to the scalar per-group loop (asserted by tests): predictions are
//! scattered back and summed in group order, so even the float
//! accumulation order is unchanged.

use std::collections::HashMap;

use crate::model::ModelGraph;
use crate::thor::parse::{parse, Position};
use crate::thor::store::GpStore;

#[derive(Debug, thiserror::Error)]
pub enum EstimateError {
    #[error("family '{0}' has no fitted GP for device '{1}' — profile it first")]
    MissingFamily(String, String),
}

/// An energy estimate with per-layer attribution.
#[derive(Clone, Debug)]
pub struct Estimate {
    /// Joules per training iteration.
    pub energy_per_iter: f64,
    /// Sum of per-layer predictive variances (independence assumption).
    pub variance: f64,
    /// (family id, raw features, layer estimate J) per group.
    pub per_layer: Vec<(String, Vec<f64>, f64)>,
}

impl Estimate {
    /// Total energy for `iterations` iterations.
    pub fn total(&self, iterations: usize) -> f64 {
        self.energy_per_iter * iterations as f64
    }
}

/// Raw channel features of a group, by position (paper §3.2: output
/// channels for input layers, input channels for output layers, both for
/// hidden layers).  Output layers are characterized by their *effective*
/// input width (flattened for conv producers).
fn features(g: &crate::thor::parse::Group) -> Vec<f64> {
    match g.key.position {
        Position::Input => vec![g.anchor.c_out as f64],
        Position::Output => vec![g.anchor.c_in as f64],
        Position::Hidden => vec![g.anchor.c_in as f64, g.anchor.c_out as f64],
    }
}

/// Memoized per-family predictions keyed by (device, family id) and
/// feature bits — device is part of the key, so one cache can safely
/// span a sweep that touches several devices.  Thread one cache through
/// a candidate sweep (`pruning`) so repeated queries of the same family
/// at the same widths skip the GP entirely; cached values are exactly
/// what `predict_raw` would return, so results are unchanged.
///
/// **Precondition:** the cache is a memo of one fixed [`GpStore`]
/// snapshot.  It has no invalidation hook, so if a family is
/// (re)profiled after entries were cached, drop the cache and start a
/// fresh one — stale hits would silently mix old-GP and new-GP values.
#[derive(Default)]
pub struct EstimateCache {
    /// `"{device}|{family}"` (the [`GpStore`] key convention) → memo.
    map: HashMap<String, HashMap<Vec<u64>, (f64, f64)>>,
    pub hits: u64,
    pub misses: u64,
}

impl EstimateCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.map.values().map(|m| m.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.map.values().all(|m| m.is_empty())
    }
}

/// f64 features as exact hash keys (bit patterns; the features are
/// channel counts, so NaN never appears).
fn feat_key(feats: &[f64]) -> Vec<u64> {
    feats.iter().map(|f| f.to_bits()).collect()
}

/// Estimate a model's per-iteration training energy on `device`.
pub fn estimate(store: &GpStore, device: &str, model: &ModelGraph) -> Result<Estimate, EstimateError> {
    estimate_cached(store, device, model, &mut EstimateCache::new())
}

/// [`estimate`] with a caller-owned memo cache.  Queries are batched per
/// family: misses of one family go through a single `predict_batch`
/// call, hits skip the GP.  Per-layer results are scattered back and
/// folded in group order, so the output is bit-identical to the scalar
/// per-group loop regardless of cache state.
pub fn estimate_cached(
    store: &GpStore,
    device: &str,
    model: &ModelGraph,
    cache: &mut EstimateCache,
) -> Result<Estimate, EstimateError> {
    let parsed = parse(model);
    let n = parsed.groups.len();
    let feats: Vec<Vec<f64>> = parsed.groups.iter().map(features).collect();
    let fam_ids: Vec<String> = parsed.families.iter().map(|f| f.id()).collect();

    // group indices per family (first-appearance order = group order of
    // each family's first member, so the "first missing family" error is
    // the same one the scalar loop would report)
    let mut by_fam: Vec<Vec<usize>> = vec![Vec::new(); fam_ids.len()];
    for (gi, &fi) in parsed.assignment.iter().enumerate() {
        by_fam[fi].push(gi);
    }

    let mut per_layer_mv: Vec<(f64, f64)> = vec![(0.0, 0.0); n];
    for (fi, gidx) in by_fam.iter().enumerate() {
        if gidx.is_empty() {
            continue;
        }
        let fam = &fam_ids[fi];
        let stored = store
            .get(device, fam)
            .ok_or_else(|| EstimateError::MissingFamily(fam.clone(), device.to_string()))?;
        let fam_cache = cache.map.entry(format!("{device}|{fam}")).or_default();
        // one feat_key per missed group, reused for dedup + insertion
        let mut misses: Vec<(usize, Vec<u64>)> = Vec::new();
        for &gi in gidx {
            let key = feat_key(&feats[gi]);
            match fam_cache.get(&key) {
                Some(&mv) => {
                    per_layer_mv[gi] = mv;
                    cache.hits += 1;
                }
                None => {
                    misses.push((gi, key));
                    cache.misses += 1;
                }
            }
        }
        if !misses.is_empty() {
            // dedup identical features within the call: ResNet repeats
            // the same (family, width) dozens of times, and each unique
            // query costs an O(n²) posterior
            let mut uniq: Vec<Vec<f64>> = Vec::new();
            let mut slot_of: HashMap<&[u64], usize> = HashMap::new();
            let slots: Vec<usize> = misses
                .iter()
                .map(|(gi, key)| {
                    *slot_of.entry(key.as_slice()).or_insert_with(|| {
                        uniq.push(feats[*gi].clone());
                        uniq.len() - 1
                    })
                })
                .collect();
            let mv = stored.predict_raw_batch(&uniq);
            drop(slot_of);
            for ((gi, key), &slot) in misses.into_iter().zip(&slots) {
                per_layer_mv[gi] = mv[slot];
                fam_cache.insert(key, mv[slot]);
            }
        }
    }

    // fold in group order: same float accumulation order as the scalar
    // per-group loop
    let mut energy = 0.0;
    let mut variance = 0.0;
    let mut per_layer = Vec::with_capacity(n);
    for (gi, feat) in feats.into_iter().enumerate() {
        let (m, v) = per_layer_mv[gi];
        let m = m.max(0.0); // energies are physical
        energy += m;
        variance += v;
        per_layer.push((fam_ids[parsed.assignment[gi]].clone(), feat, m));
    }
    Ok(Estimate { energy_per_iter: energy, variance, per_layer })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::{GpModel, KernelKind};
    use crate::model::zoo;
    use crate::thor::store::StoredGp;

    /// A store whose GPs encode a known linear function of features so
    /// the additive sum is checkable in closed form.
    fn synthetic_store(model: &ModelGraph, device: &str, coef: f64) -> GpStore {
        let mut store = GpStore::new();
        add_synthetic(&mut store, model, device, coef);
        store
    }

    fn add_synthetic(store: &mut GpStore, model: &ModelGraph, device: &str, coef: f64) {
        let parsed = parse(model);
        for fam in &parsed.families {
            let tmpl = parsed.template(fam).unwrap();
            let dim = match fam.position {
                Position::Hidden => 2,
                _ => 1,
            };
            let x_max = match fam.position {
                Position::Input => vec![tmpl.anchor.c_out as f64 * 2.0],
                Position::Output => vec![tmpl.anchor.c_in as f64 * 2.0],
                Position::Hidden => vec![tmpl.anchor.c_in as f64 * 2.0, tmpl.anchor.c_out as f64 * 2.0],
            };
            // fit an (almost) linear GP: y = coef * sum(features_norm)
            let grid: Vec<Vec<f64>> = if dim == 1 {
                (0..9).map(|i| vec![i as f64 / 8.0]).collect()
            } else {
                let mut v = Vec::new();
                for i in 0..5 {
                    for j in 0..5 {
                        v.push(vec![i as f64 / 4.0, j as f64 / 4.0]);
                    }
                }
                v
            };
            let ys: Vec<f64> = grid.iter().map(|p| coef * p.iter().sum::<f64>()).collect();
            let gp = GpModel::fit(KernelKind::Matern52, grid, &ys).unwrap();
            store.insert(
                device,
                &fam.id(),
                StoredGp { gp, x_max, log_x: false, log_y: false, device_seconds: 1.0, fit_seconds: 0.1, converged: true },
            );
        }
    }

    #[test]
    fn estimate_sums_per_layer_terms() {
        let g = zoo::cnn5(&[8, 16, 32, 64], 28, 10);
        let store = synthetic_store(&g, "xavier", 10.0);
        let est = estimate(&store, "xavier", &g).unwrap();
        let sum: f64 = est.per_layer.iter().map(|(_, _, e)| e).sum();
        assert!((est.energy_per_iter - sum).abs() < 1e-9);
        assert_eq!(est.per_layer.len(), 5);
        assert!(est.energy_per_iter > 0.0);
    }

    #[test]
    fn missing_family_is_reported() {
        let g = zoo::cnn5(&[8, 16, 32, 64], 28, 10);
        let store = synthetic_store(&g, "xavier", 10.0);
        match estimate(&store, "oppo", &g) {
            Err(EstimateError::MissingFamily(_, dev)) => assert_eq!(dev, "oppo"),
            other => panic!("expected MissingFamily, got {other:?}"),
        }
    }

    #[test]
    fn repeated_families_reuse_one_gp() {
        // ResNet-56 has 55 conv groups but ~an order fewer families; every
        // group must still get a per-layer term.
        let g = zoo::resnet(20, 8, 10);
        let store = synthetic_store(&g, "server", 5.0);
        let est = estimate(&store, "server", &g).unwrap();
        let parsed = parse(&g);
        assert_eq!(est.per_layer.len(), parsed.groups.len());
        assert!(parsed.families.len() < parsed.groups.len());
    }

    #[test]
    fn batched_estimate_matches_scalar_loop_exactly() {
        // The per-family batched path must reproduce the naive per-group
        // scalar loop bit-for-bit (ResNet has many groups per family, so
        // this exercises real batching).
        let g = zoo::resnet(20, 8, 10);
        let store = synthetic_store(&g, "xavier", 7.0);
        let est = estimate(&store, "xavier", &g).unwrap();

        let parsed = parse(&g);
        let mut energy = 0.0;
        let mut variance = 0.0;
        for (i, grp) in parsed.groups.iter().enumerate() {
            let fam = grp.key.id();
            let stored = store.get("xavier", &fam).unwrap();
            let feats = features(grp);
            let (m, v) = stored.predict_raw(&feats);
            let m = m.max(0.0);
            energy += m;
            variance += v;
            let (got_fam, got_feats, got_m) = &est.per_layer[i];
            assert_eq!(*got_fam, fam);
            assert_eq!(*got_feats, feats);
            assert_eq!(got_m.to_bits(), m.to_bits(), "group {i} mean diverged");
        }
        assert_eq!(est.energy_per_iter.to_bits(), energy.to_bits());
        assert_eq!(est.variance.to_bits(), variance.to_bits());
    }

    #[test]
    fn cached_estimate_hits_and_matches() {
        let g = zoo::resnet(20, 8, 10);
        let store = synthetic_store(&g, "server", 3.0);
        let mut cache = EstimateCache::new();
        let a = estimate_cached(&store, "server", &g, &mut cache).unwrap();
        assert!(cache.misses > 0 && cache.len() > 0);
        // ResNet repeats families at identical widths: the dedup keeps
        // unique entries below the group count, and a second pass over
        // the same model is all hits.
        assert!(cache.len() < parse(&g).groups.len(), "dedup should collapse repeats");
        let misses_after_first = cache.misses;
        let b = estimate_cached(&store, "server", &g, &mut cache).unwrap();
        assert_eq!(cache.misses, misses_after_first, "second pass should not miss");
        assert!(cache.hits as usize >= parse(&g).groups.len());
        assert_eq!(a.energy_per_iter.to_bits(), b.energy_per_iter.to_bits());
        assert_eq!(a.variance.to_bits(), b.variance.to_bits());
        // and the cached result equals the uncached one
        let c = estimate(&store, "server", &g).unwrap();
        assert_eq!(a.energy_per_iter.to_bits(), c.energy_per_iter.to_bits());
    }

    #[test]
    fn cache_keys_by_device() {
        // One cache across two devices must not cross-contaminate: the
        // same family ids exist on both, with different fitted surfaces.
        let g = zoo::cnn5(&[8, 16, 32, 64], 16, 10);
        let mut store = synthetic_store(&g, "xavier", 10.0);
        add_synthetic(&mut store, &g, "server", 3.0);
        let mut cache = EstimateCache::new();
        let a = estimate_cached(&store, "xavier", &g, &mut cache).unwrap();
        let b = estimate_cached(&store, "server", &g, &mut cache).unwrap();
        assert_eq!(
            a.energy_per_iter.to_bits(),
            estimate(&store, "xavier", &g).unwrap().energy_per_iter.to_bits()
        );
        assert_eq!(
            b.energy_per_iter.to_bits(),
            estimate(&store, "server", &g).unwrap().energy_per_iter.to_bits()
        );
        assert!((a.energy_per_iter - b.energy_per_iter).abs() > 1e-6, "devices must differ");
    }

    #[test]
    fn wider_model_estimates_higher() {
        let narrow = zoo::cnn5(&[4, 8, 16, 32], 28, 10);
        let wide = zoo::cnn5(&[8, 16, 32, 64], 28, 10);
        // one store fitted on the wide parse covers both (same families)
        let store = synthetic_store(&wide, "tx2", 20.0);
        let e_n = estimate(&store, "tx2", &narrow).unwrap().energy_per_iter;
        let e_w = estimate(&store, "tx2", &wide).unwrap().energy_per_iter;
        assert!(e_w > e_n, "{e_w} vs {e_n}");
    }
}
