//! Additive estimation (paper §3.4, eq. 4): parse the target model, look
//! up each group's family GP, predict at the group's channel features,
//! and sum:
//!
//! Ê_model = Ê_input(C₁) + Σ Ê_hidden(C_{i−1}, C_i) + Ê_output(C_{n−1})

use crate::model::ModelGraph;
use crate::thor::parse::{parse, Position};
use crate::thor::profiler::fc_in_after;
use crate::thor::store::GpStore;

#[derive(Debug, thiserror::Error)]
pub enum EstimateError {
    #[error("family '{0}' has no fitted GP for device '{1}' — profile it first")]
    MissingFamily(String, String),
}

/// An energy estimate with per-layer attribution.
#[derive(Clone, Debug)]
pub struct Estimate {
    /// Joules per training iteration.
    pub energy_per_iter: f64,
    /// Sum of per-layer predictive variances (independence assumption).
    pub variance: f64,
    /// (family id, raw features, layer estimate J) per group.
    pub per_layer: Vec<(String, Vec<f64>, f64)>,
}

impl Estimate {
    /// Total energy for `iterations` iterations.
    pub fn total(&self, iterations: usize) -> f64 {
        self.energy_per_iter * iterations as f64
    }
}

/// Raw channel features of a group, by position (paper §3.2: output
/// channels for input layers, input channels for output layers, both for
/// hidden layers).  Output layers are characterized by their *effective*
/// input width (flattened for conv producers).
fn features(g: &crate::thor::parse::Group) -> Vec<f64> {
    match g.key.position {
        Position::Input => vec![g.anchor.c_out as f64],
        Position::Output => vec![g.anchor.c_in as f64],
        Position::Hidden => vec![g.anchor.c_in as f64, g.anchor.c_out as f64],
    }
}

/// Estimate a model's per-iteration training energy on `device`.
pub fn estimate(store: &GpStore, device: &str, model: &ModelGraph) -> Result<Estimate, EstimateError> {
    let parsed = parse(model);
    let mut energy = 0.0;
    let mut variance = 0.0;
    let mut per_layer = Vec::with_capacity(parsed.groups.len());
    for g in &parsed.groups {
        let fam = g.key.id();
        let stored = store
            .get(device, &fam)
            .ok_or_else(|| EstimateError::MissingFamily(fam.clone(), device.to_string()))?;
        let feats = features(g);
        let (m, v) = stored.predict_raw(&feats);
        let m = m.max(0.0); // energies are physical
        energy += m;
        variance += v;
        per_layer.push((fam, feats, m));
    }
    let _ = fc_in_after; // (re-exported for variant symmetry; silence lint)
    Ok(Estimate { energy_per_iter: energy, variance, per_layer })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::{GpModel, KernelKind};
    use crate::model::zoo;
    use crate::thor::store::StoredGp;

    /// A store whose GPs encode a known linear function of features so
    /// the additive sum is checkable in closed form.
    fn synthetic_store(model: &ModelGraph, device: &str, coef: f64) -> GpStore {
        let parsed = parse(model);
        let mut store = GpStore::new();
        for fam in &parsed.families {
            let tmpl = parsed.template(fam).unwrap();
            let dim = match fam.position {
                Position::Hidden => 2,
                _ => 1,
            };
            let x_max = match fam.position {
                Position::Input => vec![tmpl.anchor.c_out as f64 * 2.0],
                Position::Output => vec![tmpl.anchor.c_in as f64 * 2.0],
                Position::Hidden => vec![tmpl.anchor.c_in as f64 * 2.0, tmpl.anchor.c_out as f64 * 2.0],
            };
            // fit an (almost) linear GP: y = coef * sum(features_norm)
            let grid: Vec<Vec<f64>> = if dim == 1 {
                (0..9).map(|i| vec![i as f64 / 8.0]).collect()
            } else {
                let mut v = Vec::new();
                for i in 0..5 {
                    for j in 0..5 {
                        v.push(vec![i as f64 / 4.0, j as f64 / 4.0]);
                    }
                }
                v
            };
            let ys: Vec<f64> = grid.iter().map(|p| coef * p.iter().sum::<f64>()).collect();
            let gp = GpModel::fit(KernelKind::Matern52, grid, &ys).unwrap();
            store.insert(
                device,
                &fam.id(),
                StoredGp { gp, x_max, log_x: false, log_y: false, device_seconds: 1.0, fit_seconds: 0.1, converged: true },
            );
        }
        store
    }

    #[test]
    fn estimate_sums_per_layer_terms() {
        let g = zoo::cnn5(&[8, 16, 32, 64], 28, 10);
        let store = synthetic_store(&g, "xavier", 10.0);
        let est = estimate(&store, "xavier", &g).unwrap();
        let sum: f64 = est.per_layer.iter().map(|(_, _, e)| e).sum();
        assert!((est.energy_per_iter - sum).abs() < 1e-9);
        assert_eq!(est.per_layer.len(), 5);
        assert!(est.energy_per_iter > 0.0);
    }

    #[test]
    fn missing_family_is_reported() {
        let g = zoo::cnn5(&[8, 16, 32, 64], 28, 10);
        let store = synthetic_store(&g, "xavier", 10.0);
        match estimate(&store, "oppo", &g) {
            Err(EstimateError::MissingFamily(_, dev)) => assert_eq!(dev, "oppo"),
            other => panic!("expected MissingFamily, got {other:?}"),
        }
    }

    #[test]
    fn repeated_families_reuse_one_gp() {
        // ResNet-56 has 55 conv groups but ~an order fewer families; every
        // group must still get a per-layer term.
        let g = zoo::resnet(20, 8, 10);
        let store = synthetic_store(&g, "server", 5.0);
        let est = estimate(&store, "server", &g).unwrap();
        let parsed = parse(&g);
        assert_eq!(est.per_layer.len(), parsed.groups.len());
        assert!(parsed.families.len() < parsed.groups.len());
    }

    #[test]
    fn wider_model_estimates_higher() {
        let narrow = zoo::cnn5(&[4, 8, 16, 32], 28, 10);
        let wide = zoo::cnn5(&[8, 16, 32, 64], 28, 10);
        // one store fitted on the wide parse covers both (same families)
        let store = synthetic_store(&wide, "tx2", 20.0);
        let e_n = estimate(&store, "tx2", &narrow).unwrap().energy_per_iter;
        let e_w = estimate(&store, "tx2", &wide).unwrap().energy_per_iter;
        assert!(e_w > e_n, "{e_w} vs {e_n}");
    }
}
