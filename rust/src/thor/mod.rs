//! THOR core (paper §3): layer parsing, variant-network profiling with
//! layer-wise subtractivity, GP fitting with guided (active-learning)
//! profiling, and additive estimation.
//!
//! Flow (Fig 3):
//!
//! 1. [`parse`] dissects a model into input / hidden / output layer
//!    *families* (dedup by layer type + hyper-parameters, non-parametric
//!    layers grouped with their producer).
//! 2. [`profiler`] builds 1-/2-/3-layer variant networks per family,
//!    trains them on the (simulated) device and recovers per-layer
//!    energies via subtractivity (eqs. 1–2).
//! 3. [`fit`] drives profiling with the GP max-variance acquisition and
//!    the paper's end conditions (point budget / 5 % variance).
//! 4. [`estimator`] sums per-layer GP means over any parsed model (eq. 4).
//!
//! Fitted GPs are persisted per `(device, family)` in [`store`] and are
//! reusable across models sharing families — the paper's "one-time
//! endeavor" property.

pub mod checkpoint;
pub mod estimator;
pub mod fit;
pub mod measure;
pub mod parse;
pub mod pipeline;
pub mod profiler;
pub mod store;

pub use checkpoint::{Checkpoint, Checkpointer, FitJournal};
pub use estimator::{
    estimate_batch_shared, estimate_shared, Estimate, EstimateCache, SharedEstimateCache,
};
pub use fit::Batch;
pub use measure::{AbortAfter, LocalMeasurer, MeasureError, MeasureRequest, Measurement, Measurer};
pub use parse::{FamilyKey, ParsedModel, Position};
pub use pipeline::{ProfileOptions, Thor, ThorConfig};
