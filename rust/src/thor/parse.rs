//! Layer parsing (paper §3.2 "Layer Parsing"):
//!
//! * parametric layers anchor *groups*; non-parametric successors (ReLU,
//!   pooling, dropout, BN — BN is fused with its producer by frameworks)
//!   are folded into the preceding group;
//! * the first group is the **input layer**, the last the **output
//!   layer**, everything between a **hidden layer**;
//! * groups dedup into *families* by layer type and hyper-parameters
//!   (kernel size, stride, spatial size, batch) — "layers with different
//!   kernel sizes, steps, and batchsizes are encoded as different layers
//!   since their energy cost patterns have a large gap";
//! * families are characterized by output channels (input layers), input
//!   channels (output layers) or both (hidden layers).

use crate::model::{LayerKind, LayerSpec, ModelGraph};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Position {
    Input,
    Hidden,
    Output,
}

/// Dedup key for a layer family.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct FamilyKey {
    pub position: Position,
    /// Anchor kind + structural hyper-parameters.
    pub kind: LayerKind,
    /// Input spatial size of the anchor.
    pub h: usize,
    pub w: usize,
    pub batch: usize,
    /// Names of grouped non-parametric successors (affects the group's
    /// energy, so it is part of the identity).
    pub group_sig: String,
}

impl FamilyKey {
    /// Stable string id (store keys, wire protocol).
    pub fn id(&self) -> String {
        let pos = match self.position {
            Position::Input => "in",
            Position::Hidden => "hid",
            Position::Output => "out",
        };
        let kind = match &self.kind {
            LayerKind::Conv2d { kernel, stride, padded } => {
                format!("conv{kernel}s{stride}{}", if *padded { "p" } else { "v" })
            }
            k => k.name().to_string(),
        };
        format!("{pos}:{kind}:h{}w{}b{}:{}", self.h, self.w, self.batch, self.group_sig)
    }
}

/// One group: anchor parametric layer + its grouped successors, template
/// (reference-model) widths.
#[derive(Clone, Debug)]
pub struct Group {
    pub anchor: LayerSpec,
    pub tail: Vec<LayerSpec>,
    pub key: FamilyKey,
    /// Index of the anchor in the source graph.
    pub anchor_idx: usize,
}

impl Group {
    /// Output elements per sample after the whole group (drives the FC
    /// input width of downstream variant construction).
    pub fn out_elems_per_sample(&self) -> usize {
        let mut hw = self.anchor.out_hw();
        let c = self.anchor.c_out;
        for t in &self.tail {
            let probe = LayerSpec { h: hw.0, w: hw.1, ..t.clone() };
            hw = probe.out_hw();
        }
        match self.anchor.kind {
            LayerKind::Fc => c,
            LayerKind::Embedding | LayerKind::Lstm | LayerKind::Attention { .. } => c * self.anchor.h,
            _ => c * hw.0 * hw.1,
        }
    }

    /// Clone the group with new channel widths (variant construction and
    /// estimation share this).
    pub fn with_channels(&self, c_in: usize, c_out: usize) -> Group {
        let mut anchor = self.anchor.clone();
        anchor.c_in = c_in;
        anchor.c_out = c_out;
        let mut hw = anchor.out_hw();
        let tail = self
            .tail
            .iter()
            .map(|t| {
                let nt = LayerSpec { c_in: c_out, c_out, h: hw.0, w: hw.1, ..t.clone() };
                hw = nt.out_hw();
                nt
            })
            .collect();
        Group { anchor, tail, key: self.key.clone(), anchor_idx: self.anchor_idx }
    }

    pub fn layers(&self) -> Vec<LayerSpec> {
        let mut v = vec![self.anchor.clone()];
        v.extend(self.tail.iter().cloned());
        v
    }
}

/// A model parsed into positioned groups + their family assignment.
#[derive(Clone, Debug)]
pub struct ParsedModel {
    pub name: String,
    pub groups: Vec<Group>,
    /// Distinct families, in first-appearance order.
    pub families: Vec<FamilyKey>,
    /// `groups[i]` belongs to `families[assignment[i]]`.
    pub assignment: Vec<usize>,
}

impl ParsedModel {
    pub fn input_groups(&self) -> impl Iterator<Item = &Group> {
        self.groups.iter().filter(|g| g.key.position == Position::Input)
    }

    pub fn output_groups(&self) -> impl Iterator<Item = &Group> {
        self.groups.iter().filter(|g| g.key.position == Position::Output)
    }

    pub fn hidden_groups(&self) -> impl Iterator<Item = &Group> {
        self.groups.iter().filter(|g| g.key.position == Position::Hidden)
    }

    /// Representative (template) group of a family.
    pub fn template(&self, fam: &FamilyKey) -> Option<&Group> {
        self.groups.iter().find(|g| &g.key == fam)
    }
}

/// Parse a model graph into groups and families.
pub fn parse(g: &ModelGraph) -> ParsedModel {
    // 1. group non-parametric layers with their preceding parametric layer
    let mut raw_groups: Vec<(usize, LayerSpec, Vec<LayerSpec>)> = Vec::new();
    for (i, l) in g.layers.iter().enumerate() {
        if l.kind.is_parametric() {
            raw_groups.push((i, l.clone(), Vec::new()));
        } else if let Some(last) = raw_groups.last_mut() {
            last.2.push(l.clone());
        }
        // leading non-parametric layers (rare) are dropped: they carry no
        // channels to characterize and negligible energy.
    }
    assert!(raw_groups.len() >= 2, "need at least input and output layers");

    // 2. positions
    let n = raw_groups.len();
    let mut groups = Vec::with_capacity(n);
    for (idx, (anchor_idx, anchor, tail)) in raw_groups.into_iter().enumerate() {
        let position = if idx == 0 {
            Position::Input
        } else if idx == n - 1 {
            Position::Output
        } else {
            Position::Hidden
        };
        let group_sig: String = tail.iter().map(|t| short_sig(&t.kind)).collect::<Vec<_>>().join("-");
        let key = FamilyKey {
            position,
            kind: anchor.kind.clone(),
            h: anchor.h,
            w: anchor.w,
            batch: anchor.batch,
            group_sig,
        };
        groups.push(Group { anchor, tail, key, anchor_idx });
    }

    // 3. dedup into families
    let mut families: Vec<FamilyKey> = Vec::new();
    let mut assignment = Vec::with_capacity(groups.len());
    for grp in &groups {
        match families.iter().position(|f| f == &grp.key) {
            Some(i) => assignment.push(i),
            None => {
                families.push(grp.key.clone());
                assignment.push(families.len() - 1);
            }
        }
    }
    ParsedModel { name: g.name.clone(), groups, families, assignment }
}

fn short_sig(k: &LayerKind) -> String {
    match k {
        LayerKind::MaxPool { size } => format!("mp{size}"),
        LayerKind::BatchNorm => "bn".into(),
        LayerKind::Relu => "r".into(),
        LayerKind::Dropout => "do".into(),
        LayerKind::Softmax => "sm".into(),
        LayerKind::LayerNorm => "ln".into(),
        LayerKind::ResidualAdd => "ra".into(),
        other => other.name().into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn cnn5_parses_to_expected_families() {
        let p = parse(&zoo::cnn5(&[32, 64, 128, 256], 28, 10));
        // 4 conv groups + 1 fc group
        assert_eq!(p.groups.len(), 5);
        assert_eq!(p.groups[0].key.position, Position::Input);
        assert_eq!(p.groups[4].key.position, Position::Output);
        // conv groups at different spatial sizes are distinct families
        let hidden: Vec<_> = p.hidden_groups().collect();
        assert_eq!(hidden.len(), 3);
        let fam_count = p.families.len();
        assert_eq!(fam_count, 5); // all distinct (h/w differ per block)
    }

    #[test]
    fn resnet_dedups_repeated_blocks() {
        let g = zoo::resnet(56, 16, 10);
        let p = parse(&g);
        let convs = p.groups.iter().filter(|gr| matches!(gr.key.kind, LayerKind::Conv2d { .. })).count();
        // 55 conv groups but far fewer families thanks to modular design
        assert_eq!(convs, 55);
        assert!(p.families.len() <= 12, "families {}", p.families.len());
    }

    #[test]
    fn resnet110_has_same_family_count_as_resnet56() {
        // deeper stacks repeat the same blocks -> identical family sets
        let f56 = parse(&zoo::resnet(56, 16, 10)).families.len();
        let f110 = parse(&zoo::resnet(110, 16, 10)).families.len();
        assert_eq!(f56, f110);
    }

    #[test]
    fn grouping_folds_non_parametric_tail() {
        let p = parse(&zoo::cnn5(&[8, 16, 32, 64], 28, 10));
        // each conv group carries bn + relu + maxpool
        let g0 = &p.groups[0];
        assert_eq!(g0.tail.len(), 3);
        assert_eq!(g0.key.group_sig, "bn-r-mp2");
    }

    #[test]
    fn with_channels_rescales_consistently() {
        let p = parse(&zoo::cnn5(&[8, 16, 32, 64], 28, 10));
        let g = p.groups[1].with_channels(4, 12);
        assert_eq!(g.anchor.c_in, 4);
        assert_eq!(g.anchor.c_out, 12);
        for t in &g.tail {
            assert_eq!(t.c_out, 12);
        }
    }

    #[test]
    fn out_elems_accounts_for_pooling() {
        let p = parse(&zoo::cnn5(&[8, 16, 32, 64], 28, 10));
        // block 1: conv(28x28, c=8) + pool2 -> 14*14*8
        assert_eq!(p.groups[0].out_elems_per_sample(), 14 * 14 * 8);
    }

    #[test]
    fn family_ids_stable_and_distinct() {
        let p = parse(&zoo::lenet5(&[6, 16, 120, 84], 10));
        let ids: Vec<String> = p.families.iter().map(|f| f.id()).collect();
        let mut dedup = ids.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), ids.len());
    }

    #[test]
    fn lstm_families() {
        let p = parse(&zoo::lstm(64, &[128, 128], 2000, 32, 10));
        assert_eq!(p.groups[0].key.kind, LayerKind::Embedding);
        let hidden: Vec<_> = p.hidden_groups().collect();
        // two lstm groups + nothing else parametric between
        assert!(hidden.iter().all(|g| matches!(g.key.kind, LayerKind::Lstm)));
        assert_eq!(hidden.len(), 2);
    }
}
