//! Variant-network construction + layer-wise subtractivity (paper §3.2
//! "Profiling Process", eqs. 1–2).
//!
//! * **output family**: the output group alone is trained as a complete
//!   model — `E_output(C)` measured directly;
//! * **input family**: input group (width `C`) + output group;
//!   `E_input(C) = E_{in+out} − Ê_output(·)` (eq. 1);
//! * **hidden family**: minimal input + hidden (widths `a → b`) + output;
//!   `E_hidden(a, b) = E_variant − Ê_input(·) − Ê_output(·)` (eq. 2).
//!
//! Variant networks are lowered + fused exactly like real models, so the
//! measurements inherit every runtime effect (fusion, occupancy, DVFS,
//! meter noise).

use crate::model::{LayerKind, ModelGraph};
use crate::simdevice::Device;
use crate::thor::parse::{Group, ParsedModel};
use crate::workload::{fusion::fuse, lower::lower, Trace};

/// FC input width produced by a group at its current widths (conv-like
/// groups flatten spatially; recurrent/attention groups hand over their
/// feature dim).
pub fn fc_in_after(g: &Group) -> usize {
    match g.anchor.kind {
        LayerKind::Lstm => g.anchor.c_out, // last hidden state
        LayerKind::Attention { .. } | LayerKind::Embedding => g.anchor.c_out,
        LayerKind::Fc => g.anchor.c_out,
        _ => g.out_elems_per_sample(),
    }
}

/// Build the 1-layer output-family variant at input width `c_in`.
pub fn output_variant(output: &Group, c_in: usize) -> ModelGraph {
    let g = output.with_channels(c_in.max(1), output.anchor.c_out);
    ModelGraph::new("variant_out", g.layers())
}

/// Build the 2-layer input+output variant at input width `c_out`.
/// Returns (graph, output-layer input width used).
pub fn input_variant(input: &Group, output: &Group, c_out: usize) -> (ModelGraph, usize) {
    let gi = input.with_channels(input.anchor.c_in, c_out.max(1));
    let fc_in = fc_in_after(&gi).max(1);
    let go = output.with_channels(fc_in, output.anchor.c_out);
    let mut layers = gi.layers();
    layers.extend(go.layers());
    (ModelGraph::new("variant_in", layers), fc_in)
}

/// Build the 3-layer input+hidden+output variant at hidden widths
/// `(a, b)`.  The input group runs at minimal width (the paper starts
/// profiling from the bound values; a thin input keeps the subtracted
/// terms small).  Returns (graph, input width used, output input width).
pub fn hidden_variant(
    input: &Group,
    hidden: &Group,
    output: &Group,
    a: usize,
    b: usize,
) -> (ModelGraph, usize, usize) {
    let thin = 1usize;
    let gi = input.with_channels(input.anchor.c_in, thin);
    let gh = hidden.with_channels(a.max(1), b.max(1));
    let fc_in = fc_in_after(&gh).max(1);
    let go = output.with_channels(fc_in, output.anchor.c_out);
    let mut layers = gi.layers();
    layers.extend(gh.layers());
    layers.extend(go.layers());
    (ModelGraph::new("variant_hid", layers), thin, fc_in)
}

/// Lower + fuse a variant for measurement.
pub fn variant_trace(g: &ModelGraph) -> Trace {
    fuse(&lower(g))
}

/// Measure a variant: energy J/iter and total device-seconds spent.
pub fn measure(dev: &mut Device, g: &ModelGraph, iterations: usize) -> (f64, f64) {
    let m = dev.run(&variant_trace(g), iterations);
    (m.energy_per_iter(), m.time_s)
}

/// Rebuilds variant graphs from (family, channels) using the templates
/// of a reference model — every measurement backend (local, fleet
/// worker, PJRT) shares the reference architecture, so only channels
/// travel between the acquisition loop and the backend.
pub struct VariantBuilder {
    input: Group,
    output: Group,
    hidden: Vec<Group>,
}

impl VariantBuilder {
    pub fn from_reference(reference: &ModelGraph) -> Self {
        let parsed = crate::thor::parse::parse(reference);
        let input = parsed.input_groups().next().expect("input group").clone();
        let output = parsed.output_groups().next().expect("output group").clone();
        let hidden: Vec<Group> = parsed.hidden_groups().cloned().collect();
        Self { input, output, hidden }
    }

    /// Build the variant graph for a family id + raw channels.
    pub fn build(&self, family: &str, channels: &[usize]) -> anyhow::Result<ModelGraph> {
        if family == self.output.key.id() {
            return Ok(output_variant(&self.output, channels[0]));
        }
        if family == self.input.key.id() {
            return Ok(input_variant(&self.input, &self.output, channels[0]).0);
        }
        for h in &self.hidden {
            if family == h.key.id() {
                let (g, _, _) =
                    hidden_variant(&self.input, h, &self.output, channels[0], channels[1]);
                return Ok(g);
            }
        }
        Err(anyhow::anyhow!("unknown family '{family}'"))
    }
}

/// Deterministic per-job device seed: FNV-1a ([`crate::util::hash`]) over
/// (base seed ‖ family ‖ channels ‖ iterations).  Any backend measuring
/// the same request with the same base seed gets the same result, which
/// makes a whole profiling run a pure function of the request stream —
/// independent of which worker ran what, in what order (see
/// `rust/tests/fleet.rs` and `rust/tests/backend_equiv.rs`).
pub fn job_seed(base_seed: u64, family: &str, channels: &[usize], iterations: usize) -> u64 {
    let mut h = crate::util::hash::Fnv1a::new();
    h.write(&base_seed.to_le_bytes());
    h.write(family.as_bytes());
    for c in channels {
        h.write(&(*c as u64).to_le_bytes());
    }
    h.write(&(iterations as u64).to_le_bytes());
    h.finish()
}

/// Per-device-class measurement seed base: FNV-1a over (base seed ‖
/// device class).  Heterogeneous runs extend the [`job_seed`] hash
/// chain with the device class by folding the class in *here*, before
/// the per-request fold — so two requests that agree on (family,
/// channels, iterations) but target different classes never share a
/// measurement seed, while single-class runs that pass `base_seed`
/// straight to [`job_seed`] keep their PR-4 bit patterns (legacy
/// stores, goldens and `fleet1`/`fleetN` outputs are unchanged).
///
/// The rule every class-aware backend follows: class `c` of a fleet
/// with base seed `s` measures with per-job base `class_seed(s, c)` —
/// [`crate::thor::measure::LocalMeasurer`]'s multi-class mode and
/// [`crate::coordinator::DeviceWorker::with_class_seed`] both derive
/// it from this one function, which is what makes a heterogeneous
/// fleet store the byte-exact merge of per-class local stores
/// (`rust/tests/backend_equiv.rs`).
pub fn class_seed(base_seed: u64, device: &str) -> u64 {
    let mut h = crate::util::hash::Fnv1a::new();
    h.write(&base_seed.to_le_bytes());
    h.write(device.as_bytes());
    h.finish()
}

/// Channel ranges a family must be profiled over so that every later
/// query (estimation or subtraction) stays inside the fitted region.
pub struct Ranges {
    /// Output family: c_in ∈ [1, out_max].
    pub out_max: usize,
    /// Input family: c_out ∈ [1, in_max].
    pub in_max: usize,
    /// Hidden families: (c_in_max, c_out_max) aligned with
    /// `parsed.families` order (input/output entries unused).
    pub hidden_max: Vec<(usize, usize)>,
}

/// Compute ranges from the parsed reference model.
pub fn ranges(parsed: &ParsedModel) -> Ranges {
    let out_tmpl = parsed.output_groups().next().expect("no output group");
    let in_tmpl = parsed.input_groups().next().expect("no input group");

    // Output c_in must cover: its reference width, every fc_in_after of a
    // hidden/input group at max width.
    let mut out_max = out_tmpl.anchor.c_in;
    for g in parsed.groups.iter().filter(|g| g.key.position != crate::thor::Position::Output) {
        let at_max = g.with_channels(g.anchor.c_in, g.anchor.c_out);
        out_max = out_max.max(fc_in_after(&at_max));
    }

    // Input c_out must cover its reference width (hidden variants run the
    // input thin, so no extra coverage needed).
    let in_max = in_tmpl.anchor.c_out;

    let hidden_max = parsed
        .families
        .iter()
        .map(|f| {
            parsed
                .groups
                .iter()
                .filter(|g| &g.key == f)
                .map(|g| (g.anchor.c_in, g.anchor.c_out))
                .fold((1, 1), |(a, b), (c, d)| (a.max(c), b.max(d)))
        })
        .collect();

    Ranges { out_max: out_max.max(2), in_max: in_max.max(2), hidden_max }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::simdevice::{devices, Device};
    use crate::thor::parse::parse;

    fn parsed_cnn() -> ParsedModel {
        parse(&zoo::cnn5(&[16, 32, 64, 128], 28, 10))
    }

    #[test]
    fn output_variant_is_single_group() {
        let p = parsed_cnn();
        let out = p.output_groups().next().unwrap();
        let v = output_variant(out, 64);
        assert_eq!(v.layers.len(), out.layers().len());
        assert_eq!(v.layers[0].c_in, 64);
    }

    #[test]
    fn input_variant_chains_flattened_width() {
        let p = parsed_cnn();
        let (v, fc_in) = input_variant(
            p.input_groups().next().unwrap(),
            p.output_groups().next().unwrap(),
            8,
        );
        // conv 28x28 c_out=8 + pool2 -> 14*14*8 = 1568
        assert_eq!(fc_in, 14 * 14 * 8);
        let fc = v.layers.iter().find(|l| matches!(l.kind, LayerKind::Fc)).unwrap();
        assert_eq!(fc.c_in, 1568);
    }

    #[test]
    fn hidden_variant_has_three_groups() {
        let p = parsed_cnn();
        let hid = p.hidden_groups().next().unwrap();
        let (v, thin, _) = hidden_variant(
            p.input_groups().next().unwrap(),
            hid,
            p.output_groups().next().unwrap(),
            4,
            12,
        );
        assert_eq!(thin, 1);
        let convs: Vec<_> = v.layers.iter().filter(|l| matches!(l.kind, LayerKind::Conv2d { .. })).collect();
        assert_eq!(convs.len(), 2);
        assert_eq!(convs[1].c_in, 4);
        assert_eq!(convs[1].c_out, 12);
    }

    #[test]
    fn additivity_holds_on_simulator() {
        // The paper's core empirical claim (Fig 2): E(in+hid+out) ≈
        // E(in) + E(hid) + E(out) within a few percent on warm fused runs.
        let p = parsed_cnn();
        let input = p.input_groups().next().unwrap();
        let hid = p.hidden_groups().next().unwrap();
        let out = p.output_groups().next().unwrap();
        let dev_profile = devices::xavier();

        let e_of = |g: &ModelGraph| {
            crate::simdevice::exec::ideal_energy_per_iter(&dev_profile, &variant_trace(g))
        };

        let (v3, _, fc_in3) = hidden_variant(input, hid, out, 16, 32);
        let whole = e_of(&v3);

        // parts: thin-input-only variant, hidden-only, output-only
        let (v_in, fc_in1) = input_variant(input, out, 1);
        let out_v1 = e_of(&output_variant(out, fc_in1));
        let in_part = e_of(&v_in) - out_v1;
        let gh = hid.with_channels(16, 32);
        let hid_part = e_of(&ModelGraph::new("h", gh.layers()));
        let out_part = e_of(&output_variant(out, fc_in3));

        let sum = in_part + hid_part + out_part;
        let rel = ((whole - sum) / whole).abs();
        assert!(rel < 0.12, "additivity violated: whole {whole} vs sum {sum} (rel {rel})");
    }

    #[test]
    fn measure_returns_positive() {
        let p = parsed_cnn();
        let out = p.output_groups().next().unwrap();
        let mut dev = Device::new(devices::server(), 3);
        let (e, t) = measure(&mut dev, &output_variant(out, 128), 100);
        assert!(e > 0.0 && t > 0.0);
    }

    #[test]
    fn ranges_cover_reference_widths() {
        let p = parsed_cnn();
        let r = ranges(&p);
        // last conv c_out=128, pooled to 1x1 -> fc_in 128; but block3 at
        // 3x3 -> out_max >= 128. reference fc c_in = 128*1*1.
        assert!(r.out_max >= 128);
        assert_eq!(r.in_max, 16);
        let hid_fam = p.assignment[1];
        assert_eq!(r.hidden_max[hid_fam], (16, 32));
    }

    #[test]
    fn lstm_fc_in_is_units_not_seq_flattened() {
        let p = parse(&zoo::lstm(64, &[128, 128], 2000, 32, 10));
        let last_lstm = p.hidden_groups().last().unwrap();
        assert_eq!(fc_in_after(last_lstm), 128);
    }

    #[test]
    fn builder_covers_all_families() {
        let reference = zoo::cnn5(&[16, 32, 64, 128], 16, 10);
        let parsed = parse(&reference);
        let b = VariantBuilder::from_reference(&reference);
        for fam in &parsed.families {
            let dim = if fam.position == crate::thor::Position::Hidden { 2 } else { 1 };
            let chans = vec![4; dim];
            let g = b.build(&fam.id(), &chans).unwrap();
            assert!(!g.layers.is_empty());
        }
        assert!(b.build("nonexistent", &[1]).is_err());
    }

    #[test]
    fn job_seed_is_stable_and_content_sensitive() {
        let base = job_seed(42, "fam", &[4, 8], 60);
        assert_eq!(base, job_seed(42, "fam", &[4, 8], 60));
        assert_ne!(base, job_seed(43, "fam", &[4, 8], 60));
        assert_ne!(base, job_seed(42, "maf", &[4, 8], 60));
        assert_ne!(base, job_seed(42, "fam", &[8, 4], 60));
        assert_ne!(base, job_seed(42, "fam", &[4, 8], 61));
    }

    #[test]
    fn class_seed_separates_device_classes() {
        // Same request, different class → different measurement seed
        // chain; same class → stable.
        assert_eq!(class_seed(42, "xavier"), class_seed(42, "xavier"));
        assert_ne!(class_seed(42, "xavier"), class_seed(42, "tx2"));
        assert_ne!(class_seed(42, "xavier"), class_seed(43, "xavier"));
        let a = job_seed(class_seed(42, "xavier"), "fam", &[4], 60);
        let b = job_seed(class_seed(42, "tx2"), "fam", &[4], 60);
        assert_ne!(a, b, "classes share a per-request seed");
    }

    #[test]
    fn built_variant_measurable() {
        let reference = zoo::cnn5(&[16, 32, 64, 128], 16, 10);
        let b = VariantBuilder::from_reference(&reference);
        let parsed = parse(&reference);
        let fam = parsed.families[1].id();
        let g = b.build(&fam, &[4, 8]).unwrap();
        let mut dev = Device::new(devices::tx2(), 5);
        let (e, t) = measure(&mut dev, &g, 30);
        assert!(e > 0.0 && t > 0.0);
    }
}
