//! Comparison baselines from the paper's evaluation:
//!
//! * [`flops_lr`] — the proxy-based SoTA: linear regression from training
//!   FLOPs to energy (Figs 7–10 comparison arm);
//! * [`neuralpower`] — NeuralPower (Cai et al. 2017) extended to training:
//!   per-stage standalone profiling summed per layer, which overestimates
//!   because it breaks inter-op data reuse (Fig 2);
//! * [`paramcount`] — parameter-count regressor (extra ablation arm).

pub mod flops_lr;
pub mod neuralpower;
pub mod paramcount;
