//! FLOPs → energy linear regression (the paper's primary baseline,
//! Appendix A5.1 "Comparison Baseline"): measure a set of training
//! structures, regress energy-per-iteration on training FLOPs, predict
//! unseen structures from their FLOPs alone.

use crate::model::{flops::model_train_flops, ModelGraph};
use crate::simdevice::Device;
use crate::util::stats::linreg;
use crate::workload::{fusion::fuse, lower::lower};

/// Fitted FLOPs-LR baseline.
#[derive(Clone, Debug)]
pub struct FlopsLr {
    pub slope: f64,
    pub intercept: f64,
    pub n_train: usize,
}

impl FlopsLr {
    /// Fit from (model, measured energy-per-iter) pairs.
    pub fn fit(data: &[(f64, f64)]) -> Self {
        let xs: Vec<f64> = data.iter().map(|d| d.0).collect();
        let ys: Vec<f64> = data.iter().map(|d| d.1).collect();
        let (slope, intercept) = linreg(&xs, &ys);
        Self { slope, intercept, n_train: data.len() }
    }

    /// Fit by measuring `train_models` on a device.
    pub fn fit_on_device(dev: &mut Device, train_models: &[ModelGraph], iterations: usize) -> Self {
        let data: Vec<(f64, f64)> = train_models
            .iter()
            .map(|g| {
                let m = dev.run(&fuse(&lower(g)), iterations);
                (model_train_flops(g), m.energy_per_iter())
            })
            .collect();
        Self::fit(&data)
    }

    /// Predict energy-per-iteration from the architecture's FLOPs.
    pub fn predict(&self, g: &ModelGraph) -> f64 {
        (self.slope * model_train_flops(g) + self.intercept).max(0.0)
    }

    /// Ratio-style guidance used by FLOPs-guided pruning (§4.3): the
    /// predicted energy *ratio* of a pruned model equals its FLOPs ratio.
    pub fn predict_ratio(original: &ModelGraph, pruned: &ModelGraph) -> f64 {
        model_train_flops(pruned) / model_train_flops(original)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::simdevice::devices;

    #[test]
    fn recovers_linear_world() {
        // If energy really were a*flops + b, the LR is exact.
        let data: Vec<(f64, f64)> = (1..20).map(|i| {
            let f = i as f64 * 1e8;
            (f, 2e-10 * f + 0.5)
        }).collect();
        let lr = FlopsLr::fit(&data);
        assert!((lr.slope - 2e-10).abs() < 1e-15);
        assert!((lr.intercept - 0.5).abs() < 1e-9);
    }

    #[test]
    fn misestimates_occupancy_plateaus() {
        // Fig 7's mechanism: fit on random widths, then the narrowest
        // models (low FLOPs, low occupancy) are badly predicted.
        let mut dev = Device::new(devices::xavier(), 3);
        let train: Vec<ModelGraph> = crate::model::sampler::sample_n(
            crate::model::sampler::Family::Cnn5, 20, 11, 10,
        );
        let lr = FlopsLr::fit_on_device(&mut dev, &train, 60);
        let tiny = zoo::cnn5(&[1, 1, 1, 1], 28, 10);
        let truth = crate::simdevice::exec::ideal_energy_per_iter(
            &dev.profile,
            &crate::workload::fusion::fuse(&crate::workload::lower::lower(&tiny)),
        );
        let pred = lr.predict(&tiny);
        let rel = ((pred - truth) / truth).abs();
        assert!(rel > 0.15, "FLOPs-LR unexpectedly accurate on tiny model: rel {rel}");
    }

    #[test]
    fn ratio_guidance_tracks_flops() {
        let orig = zoo::cnn5(&[16, 32, 64, 128], 28, 10);
        let half = zoo::cnn5(&[8, 16, 32, 64], 28, 10);
        let r = FlopsLr::predict_ratio(&orig, &half);
        assert!(r > 0.15 && r < 0.5, "{r}"); // conv flops scale ~quadratically in width
    }
}
