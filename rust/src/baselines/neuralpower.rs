//! NeuralPower-style architecture-based estimation, extended from
//! inference to the whole training process (the paper's Fig-2
//! validation): profile each layer's forward/backward/update stages
//! *separately* with an operator-level profiler, then sum.
//!
//! Standalone stage profiling runs each op cold and unfused — inputs
//! re-materialize from DRAM, fused launches are split back apart, and
//! per-stage setup overhead is paid per measurement.  The sum therefore
//! *overestimates* the real fused training iteration, which is exactly
//! the systematic bias Fig 2 demonstrates.

use crate::model::ModelGraph;
use crate::simdevice::Device;
use crate::workload::lower::lower;
use crate::workload::Trace;

/// Per-layer stage profile of a model.
#[derive(Clone, Debug)]
pub struct StageProfile {
    /// (layer index, energy J/iter measured standalone).
    pub per_layer: Vec<(usize, f64)>,
}

impl StageProfile {
    pub fn total(&self) -> f64 {
        self.per_layer.iter().map(|p| p.1).sum()
    }
}

/// Profile every layer of `g` standalone (all three stages, unfused,
/// cold) and return the per-layer energies.  This *is* the estimate: the
/// method measures the actual target model layer-by-layer, so unlike
/// FLOPs-LR it needs device access for every new architecture.
pub fn profile_stages(dev: &mut Device, g: &ModelGraph, iterations: usize) -> StageProfile {
    let full = lower(g); // unfused: the profiler instruments op boundaries
    let mut per_layer = Vec::with_capacity(g.layers.len());
    for li in 0..g.layers.len() {
        let ops: Vec<_> = full.layer_ops(li).cloned().collect();
        if ops.is_empty() {
            continue;
        }
        let t = Trace { ops };
        let m = dev.run_cold(&t, iterations);
        per_layer.push((li, m.energy_per_iter()));
    }
    StageProfile { per_layer }
}

/// Convenience: the summed estimate (what Fig 2 plots against observed).
pub fn estimate(dev: &mut Device, g: &ModelGraph, iterations: usize) -> f64 {
    profile_stages(dev, g, iterations).total()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::simdevice::devices;
    use crate::workload::fusion::fuse;

    #[test]
    fn per_stage_sum_overestimates_fused_run() {
        // Fig 2: NeuralPower-style estimation > observation.
        let g = zoo::cnn5(&[16, 32, 64, 128], 28, 10);
        let mut dev = Device::new(devices::xavier(), 9);
        let est = estimate(&mut dev, &g, 40);
        let mut dev2 = Device::new(devices::xavier(), 9);
        let observed = dev2.run(&fuse(&lower(&g)), 40).energy_per_iter();
        assert!(
            est > 1.1 * observed,
            "expected overestimation: est {est} vs observed {observed}"
        );
    }

    #[test]
    fn covers_every_layer_with_ops() {
        let g = zoo::lenet5(&[6, 16, 120, 84], 10);
        let mut dev = Device::new(devices::tx2(), 2);
        let p = profile_stages(&mut dev, &g, 20);
        assert_eq!(p.per_layer.len(), g.layers.len());
        assert!(p.per_layer.iter().all(|&(_, e)| e >= 0.0));
    }
}
