//! Parameter-count linear regressor — a proxy-based ablation arm even
//! simpler than FLOPs-LR (mentioned in §2.3 among proxy methods:
//! "parameter size, and number of layers").

use crate::model::ModelGraph;
use crate::simdevice::Device;
use crate::util::stats::linreg;
use crate::workload::{fusion::fuse, lower::lower};

#[derive(Clone, Debug)]
pub struct ParamCountLr {
    pub slope: f64,
    pub intercept: f64,
}

impl ParamCountLr {
    pub fn fit_on_device(dev: &mut Device, train_models: &[ModelGraph], iterations: usize) -> Self {
        let xs: Vec<f64> = train_models.iter().map(|g| g.total_params() as f64).collect();
        let ys: Vec<f64> = train_models
            .iter()
            .map(|g| dev.run(&fuse(&lower(g)), iterations).energy_per_iter())
            .collect();
        let (slope, intercept) = linreg(&xs, &ys);
        Self { slope, intercept }
    }

    pub fn predict(&self, g: &ModelGraph) -> f64 {
        (self.slope * g.total_params() as f64 + self.intercept).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::sampler::{sample_n, Family};
    use crate::simdevice::devices;

    #[test]
    fn fits_and_predicts_positive() {
        let mut dev = Device::new(devices::server(), 4);
        let train = sample_n(Family::Cnn5, 10, 3, 10);
        let lr = ParamCountLr::fit_on_device(&mut dev, &train, 30);
        let test = sample_n(Family::Cnn5, 3, 4, 10);
        for g in &test {
            assert!(lr.predict(g) >= 0.0);
        }
    }
}
