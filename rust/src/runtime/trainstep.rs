//! Real training through the `cnn_train_step` / `cnn_eval` artifacts
//! (L2 JAX graph with the L1 Pallas matmul kernel inside, fwd + bwd).
//!
//! Shapes are fixed at AOT time: batch 16, 16×16×1 images, conv widths
//! (8, 16), 2 classes.  Channel *masks* are runtime inputs, so one
//! artifact serves every pruned sub-network (Fig 13).

use anyhow::Result;
#[cfg(not(feature = "pjrt"))]
use anyhow::anyhow;

#[cfg(feature = "pjrt")]
use crate::runtime::{lit_f32, lit_i32, lit_scalar_f32, to_vec_f32};
use crate::runtime::Runtime;
use crate::util::rng::Pcg64;

pub const BATCH: usize = 16;
pub const IMG: usize = 16;
pub const C1: usize = 8;
pub const C2: usize = 16;
pub const N_CLASSES: usize = 2;

/// Host-side parameter tensors (mirrors python/compile/model.py
/// init_params: He-initialized).
#[derive(Clone)]
pub struct CnnParams {
    pub w1: Vec<f32>, // (3,3,1,C1)
    pub b1: Vec<f32>, // (C1,)
    pub w2: Vec<f32>, // (3,3,C1,C2)
    pub b2: Vec<f32>, // (C2,)
    pub wf: Vec<f32>, // (4*4*C2, N_CLASSES)
    pub bf: Vec<f32>, // (N_CLASSES,)
}

impl CnnParams {
    pub fn init(seed: u64) -> Self {
        let mut rng = Pcg64::new(seed);
        let he = |rng: &mut Pcg64, n: usize, fan_in: f64| -> Vec<f32> {
            (0..n).map(|_| (rng.normal() * (2.0 / fan_in).sqrt()) as f32).collect()
        };
        Self {
            w1: he(&mut rng, 9 * C1, 9.0),
            b1: vec![0.0; C1],
            w2: he(&mut rng, 9 * C1 * C2, 9.0 * C1 as f64),
            b2: vec![0.0; C2],
            wf: he(&mut rng, 16 * C2 * N_CLASSES, 16.0 * C2 as f64),
            bf: vec![0.0; N_CLASSES],
        }
    }
}

/// One train/eval step result.
#[derive(Clone, Copy, Debug)]
pub struct StepResult {
    pub loss: f32,
    pub acc: f32,
}

pub struct TrainStep {
    pub params: CnnParams,
    pub mask1: Vec<f32>,
    pub mask2: Vec<f32>,
}

impl TrainStep {
    pub fn new(seed: u64) -> Self {
        Self { params: CnnParams::init(seed), mask1: vec![1.0; C1], mask2: vec![1.0; C2] }
    }

    /// Prune: keep only the first `keep1`/`keep2` channels (masks zeroed
    /// beyond — gradients provably stop flowing, tested in pytest).
    pub fn with_pruned(seed: u64, keep1: usize, keep2: usize) -> Self {
        let mut s = Self::new(seed);
        for i in keep1.min(C1)..C1 {
            s.mask1[i] = 0.0;
        }
        for i in keep2.min(C2)..C2 {
            s.mask2[i] = 0.0;
        }
        s
    }

    /// Stubs (no `pjrt` feature): artifact execution is unavailable; the
    /// callers (trainer, examples, Fig-13) guard on `Runtime::open`
    /// succeeding, which the stub runtime never does.
    #[cfg(not(feature = "pjrt"))]
    pub fn step(&mut self, _rt: &mut Runtime, _x: &[f32], _y: &[i32], _lr: f32) -> Result<StepResult> {
        Err(anyhow!("cnn_train_step artifact unavailable: built without the `pjrt` feature"))
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn eval(&self, _rt: &mut Runtime, _x: &[f32], _y: &[i32]) -> Result<StepResult> {
        Err(anyhow!("cnn_eval artifact unavailable: built without the `pjrt` feature"))
    }

    #[cfg(feature = "pjrt")]
    fn common_inputs(&self, x: &[f32], y: &[i32]) -> Result<Vec<xla::Literal>> {
        Ok(vec![
            lit_f32(x, &[BATCH as i64, IMG as i64, IMG as i64, 1])?,
            lit_i32(y, &[BATCH as i64])?,
            lit_f32(&self.params.w1, &[3, 3, 1, C1 as i64])?,
            lit_f32(&self.params.b1, &[C1 as i64])?,
            lit_f32(&self.params.w2, &[3, 3, C1 as i64, C2 as i64])?,
            lit_f32(&self.params.b2, &[C2 as i64])?,
            lit_f32(&self.params.wf, &[(16 * C2) as i64, N_CLASSES as i64])?,
            lit_f32(&self.params.bf, &[N_CLASSES as i64])?,
            lit_f32(&self.mask1, &[C1 as i64])?,
            lit_f32(&self.mask2, &[C2 as i64])?,
        ])
    }

    /// One SGD step on a batch: updates `self.params`, returns loss/acc.
    #[cfg(feature = "pjrt")]
    pub fn step(&mut self, rt: &mut Runtime, x: &[f32], y: &[i32], lr: f32) -> Result<StepResult> {
        let mut inputs = self.common_inputs(x, y)?;
        inputs.push(lit_scalar_f32(lr));
        let out = rt.execute("cnn_train_step", &inputs)?;
        self.params.w1 = to_vec_f32(&out[0])?;
        self.params.b1 = to_vec_f32(&out[1])?;
        self.params.w2 = to_vec_f32(&out[2])?;
        self.params.b2 = to_vec_f32(&out[3])?;
        self.params.wf = to_vec_f32(&out[4])?;
        self.params.bf = to_vec_f32(&out[5])?;
        Ok(StepResult { loss: to_vec_f32(&out[6])?[0], acc: to_vec_f32(&out[7])?[0] })
    }

    /// Forward-only evaluation on a batch.
    #[cfg(feature = "pjrt")]
    pub fn eval(&self, rt: &mut Runtime, x: &[f32], y: &[i32]) -> Result<StepResult> {
        let inputs = self.common_inputs(x, y)?;
        let out = rt.execute("cnn_eval", &inputs)?;
        Ok(StepResult { loss: to_vec_f32(&out[0])?[0], acc: to_vec_f32(&out[1])?[0] })
    }
}
