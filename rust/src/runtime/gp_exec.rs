//! Batched GP posterior through the AOT Pallas artifact — the estimation
//! hot path.  Pads the inducing set to N_INDUCING (zero alpha / zero K⁻¹
//! rows, proven exact in python/tests/test_posterior.py) and the query
//! batch to N_QUERIES per call.

use anyhow::{anyhow, Result};

use crate::gp::model::GpExport;
#[cfg(feature = "pjrt")]
use crate::runtime::{lit_f32, lit_scalar_f32, to_vec_f32};
use crate::runtime::Runtime;

pub const N_INDUCING: usize = 64;
pub const N_QUERIES: usize = 256;

pub struct GpExecutor;

/// Stub (no `pjrt` feature): the artifact path is unavailable; the native
/// [`crate::gp::GpModel::predict_batch`] path is the production fallback.
#[cfg(not(feature = "pjrt"))]
impl GpExecutor {
    pub fn posterior(
        _rt: &mut Runtime,
        _export: &GpExport,
        _queries: &[Vec<f64>],
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        Err(anyhow!(
            "artifact-backed GP posterior unavailable: built without the `pjrt` feature"
        ))
    }
}

#[cfg(feature = "pjrt")]
impl GpExecutor {
    /// Posterior (means, variances) for raw *normalized* query points
    /// through the artifact.  `export` must come from a GP fitted on ≤
    /// N_INDUCING points (the paper's end conditions guarantee this).
    /// Means/variances are returned in the GP's (possibly log) target
    /// space — the caller applies the same de-standardization as the
    /// native path.
    pub fn posterior(rt: &mut Runtime, export: &GpExport, queries: &[Vec<f64>]) -> Result<(Vec<f64>, Vec<f64>)> {
        let dim = export.xs.first().map(|x| x.len()).unwrap_or(1);
        let name = match dim {
            1 => "gp_posterior_d1",
            2 => "gp_posterior_d2",
            d => return Err(anyhow!("unsupported GP dim {d}")),
        };
        let n = export.xs.len();
        if n > N_INDUCING {
            return Err(anyhow!("inducing set {n} exceeds artifact capacity {N_INDUCING}"));
        }

        // Padded inducing tensors.
        let mut xi = vec![0f32; N_INDUCING * dim];
        for (i, x) in export.xs.iter().enumerate() {
            for (d, v) in x.iter().enumerate() {
                xi[i * dim + d] = *v as f32;
            }
        }
        let mut alpha = vec![0f32; N_INDUCING];
        for (i, a) in export.alpha.iter().enumerate() {
            alpha[i] = *a as f32;
        }
        let mut kinv = vec![0f32; N_INDUCING * N_INDUCING];
        for i in 0..n {
            for j in 0..n {
                kinv[i * N_INDUCING + j] = export.kinv[(i, j)] as f32;
            }
        }

        let xi_l = lit_f32(&xi, &[N_INDUCING as i64, dim as i64])?;
        let alpha_l = lit_f32(&alpha, &[N_INDUCING as i64])?;
        let kinv_l = lit_f32(&kinv, &[N_INDUCING as i64, N_INDUCING as i64])?;
        let ls_l = lit_scalar_f32(export.lengthscale as f32);
        let var_l = lit_scalar_f32(export.variance as f32);

        let mut means = Vec::with_capacity(queries.len());
        let mut vars = Vec::with_capacity(queries.len());
        for chunk in queries.chunks(N_QUERIES) {
            let mut xq = vec![0f32; N_QUERIES * dim];
            for (i, q) in chunk.iter().enumerate() {
                for (d, v) in q.iter().enumerate() {
                    xq[i * dim + d] = *v as f32;
                }
            }
            let xq_l = lit_f32(&xq, &[N_QUERIES as i64, dim as i64])?;
            let out = rt.execute(
                name,
                &[
                    xq_l,
                    xi_l.clone(),
                    alpha_l.clone(),
                    kinv_l.clone(),
                    ls_l.clone(),
                    var_l.clone(),
                ],
            )?;
            let m = to_vec_f32(&out[0])?;
            let v = to_vec_f32(&out[1])?;
            for i in 0..chunk.len() {
                // De-standardize exactly like GpModel::predict.
                means.push(export.y_mean + export.y_scale * m[i] as f64);
                vars.push(export.y_scale * export.y_scale * (v[i] as f64).max(0.0));
            }
        }
        Ok((means, vars))
    }
}
