//! PJRT runtime: loads the HLO-text artifacts emitted by
//! `python/compile/aot.py` and executes them on the request path.
//! Python never runs here — the artifacts are self-contained.
//!
//! * [`Runtime`] — PJRT CPU client + compiled-executable cache keyed by
//!   artifact name (one compile per artifact, reused across calls).
//! * [`GpExecutor`] — batched GP posterior through the fused L1 Pallas
//!   kernel artifact (`gp_posterior_d{1,2}`), bit-compatible with the
//!   native [`crate::gp::GpModel::predict`] path (cross-checked in
//!   `rust/tests/runtime_integration.rs`).
//! * [`TrainStep`] — the real CNN training workload (`cnn_train_step` /
//!   `cnn_eval`), used by the end-to-end example, Fig 6 and the Fig 13
//!   pruning case study.
//!
//! The `xla` crate backing PJRT is not vendored in every build
//! environment, so everything touching it is gated behind the `pjrt`
//! cargo feature.  Without the feature this module compiles to
//! API-compatible stubs: [`Runtime::open`] returns a descriptive error,
//! and every caller (integration tests, examples) already guards on the
//! artifact manifest existing / `open` succeeding, so they skip
//! gracefully instead of failing.

pub mod gp_exec;
pub mod measurer;
pub mod trainstep;

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};
#[cfg(feature = "pjrt")]
use anyhow::Context;

use crate::util::json::Json;

pub use gp_exec::GpExecutor;
pub use measurer::PjrtMeasurer;
pub use trainstep::{CnnParams, TrainStep};

/// Artifact manifest entry (from artifacts/manifest.json).
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub meta: Json,
}

impl Runtime {
    /// Default artifact location (repo-root/artifacts), overridable with
    /// `THOR_ARTIFACTS`.
    pub fn default_dir() -> PathBuf {
        std::env::var("THOR_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
    }
}

/// PJRT client + loaded executables.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    specs: HashMap<String, ArtifactSpec>,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Open the artifact directory (reads manifest.json; compiles lazily).
    pub fn open(dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let mut specs = HashMap::new();
        for (name, entry) in j.as_obj().ok_or_else(|| anyhow!("manifest not an object"))? {
            specs.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: entry.get("file").and_then(|f| f.as_str()).unwrap_or_default().to_string(),
                    kind: entry.get("kind").and_then(|k| k.as_str()).unwrap_or_default().to_string(),
                    meta: entry.clone(),
                },
            );
        }
        Ok(Self { client, dir: dir.to_path_buf(), specs, exes: HashMap::new() })
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.get(name)
    }

    /// Compile (once) and return the executable for an artifact.
    pub fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.exes.contains_key(name) {
            let spec = self
                .specs
                .get(name)
                .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
            let path = self.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            self.exes.insert(name.to_string(), exe);
        }
        Ok(self.exes.get(name).unwrap())
    }

    /// Execute an artifact on literal inputs; unwraps the result tuple
    /// (aot.py lowers with `return_tuple=True`).
    pub fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))
    }
}

/// f32 helpers for literals.
#[cfg(feature = "pjrt")]
pub fn lit_f32(values: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    xla::Literal::vec1(values)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))
}

#[cfg(feature = "pjrt")]
pub fn lit_i32(values: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    xla::Literal::vec1(values)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))
}

#[cfg(feature = "pjrt")]
pub fn lit_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::from(v)
}

#[cfg(feature = "pjrt")]
pub fn to_vec_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
}

/// Stub runtime (built without the `pjrt` feature): keeps the module API
/// so callers compile, but cannot be constructed — [`Runtime::open`]
/// always errors, and artifact-gated tests/examples skip before reaching
/// any execution path.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    _private: (),
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Always errors: PJRT execution needs the `pjrt` cargo feature (and
    /// the `xla` crate — see rust/Cargo.toml).
    pub fn open(dir: &Path) -> Result<Self> {
        Err(anyhow!(
            "PJRT runtime unavailable: built without the `pjrt` feature (artifacts dir {dir:?}); \
             add the `xla` crate to rust/Cargo.toml and build with `--features pjrt`"
        ))
    }

    pub fn spec(&self, _name: &str) -> Option<&ArtifactSpec> {
        None
    }
}
