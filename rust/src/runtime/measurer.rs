//! [`PjrtMeasurer`] — the PJRT runtime as a measurement backend stub.
//!
//! The third [`Measurer`] backend: where [`crate::thor::measure::
//! LocalMeasurer`] measures on the device simulator and
//! [`crate::coordinator::FleetMeasurer`] on a TCP fleet, this one is
//! the integration point for measuring variant trainings through real
//! compiled artifacts ([`crate::runtime::TrainStep`], the
//! `cnn_train_step` HLO with the L1 Pallas matmul inside).
//!
//! It is a deliberate **stub** at both feature levels:
//!
//! * without the `pjrt` cargo feature, [`PjrtMeasurer::open`] errors
//!   exactly like [`Runtime::open`] does (the `xla` crate is not
//!   vendored everywhere) — callers compile either way;
//! * with the feature, `open` builds the PJRT client and resolves the
//!   artifact manifest, but [`Measurer::measure_batch`] still returns a
//!   descriptive error: the current artifacts fix the architecture at
//!   AOT time (batch 16, widths 8/16 — see `runtime::trainstep`), so
//!   they cannot train the arbitrary variant widths the acquisition
//!   loop proposes.  Wiring that up needs per-variant artifact
//!   generation in `python/compile/aot.py` plus host-side energy
//!   metering — tracked in ROADMAP.md.
//!
//! The value today is the seam: `thor profile` / `thor serve` code is
//! written against `&mut dyn Measurer`, so when variant artifacts
//! exist, PJRT-backed profiling drops in without touching the pipeline.

use std::path::Path;

use anyhow::Result;

use crate::runtime::Runtime;
use crate::thor::measure::{MeasureError, MeasureRequest, Measurement, Measurer};

/// PJRT-backed measurement stub (see module docs).
pub struct PjrtMeasurer {
    /// Held for its PJRT client lifetime; unread until variant-shaped
    /// artifacts exist (see module docs).
    #[allow(dead_code)]
    runtime: Runtime,
    device: String,
}

impl PjrtMeasurer {
    /// Open the artifact directory for device `device_name`.  Without
    /// the `pjrt` feature this always errors (like [`Runtime::open`]).
    pub fn open(dir: &Path, device_name: &str) -> Result<Self> {
        Ok(Self { runtime: Runtime::open(dir)?, device: device_name.to_string() })
    }
}

impl Measurer for PjrtMeasurer {
    fn devices(&self) -> Vec<String> {
        vec![self.device.clone()]
    }

    fn measure_batch(&mut self, reqs: &[MeasureRequest]) -> Result<Vec<Measurement>, MeasureError> {
        Err(MeasureError(format!(
            "PJRT measurement is not implemented yet: {} request(s) for variant widths the \
             fixed-shape artifacts cannot train (per-variant artifact generation is tracked in \
             ROADMAP.md)",
            reqs.len()
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_without_artifacts_errors_descriptively() {
        // Both feature levels reach an error here: without `pjrt` the
        // stub Runtime::open fails, with it the missing manifest does.
        let err = PjrtMeasurer::open(Path::new("/nonexistent/artifacts"), "xavier");
        assert!(err.is_err());
    }
}
