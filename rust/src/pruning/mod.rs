//! Energy-aware pruning (paper §4.3): random channel pruning (Li et al.
//! 2022) guided by an energy estimator until the *estimated*
//! per-iteration energy reaches the budget (50 % of the original), then
//! validated against the device's actual consumption.
//!
//! The THOR-guided arm estimates absolute energies from the fitted GPs;
//! the FLOPs-guided arm uses the standard FLOPs *ratio* heuristic
//! (`E_pruned/E_orig ≈ FLOPs_pruned/FLOPs_orig`), which underestimates
//! pruned-model energy on occupancy/padding plateaus and therefore
//! overshoots the budget — the Fig 13 result.

use crate::model::{flops::model_train_flops, zoo, ModelGraph};
use crate::simdevice::Device;
use crate::thor::estimator::EstimateCache;
use crate::thor::Thor;
use crate::util::rng::Pcg64;
use crate::workload::{fusion::fuse, lower::lower};

/// How pruned candidates are scored.
pub enum Guidance<'a> {
    Thor(&'a Thor, &'a str),
    FlopsRatio { original_actual: f64 },
}

/// Result of the pruning search.
#[derive(Clone, Debug)]
pub struct PruneOutcome {
    pub channels: Vec<usize>,
    /// Energy/iter the guidance *predicted* for the chosen config.
    pub predicted: f64,
    /// Energy/iter the device actually consumes (measured).
    pub actual: f64,
    pub original_actual: f64,
}

impl PruneOutcome {
    /// Actual consumption as a fraction of the original (Fig 13 reports
    /// whether this stays below 0.5).
    pub fn actual_ratio(&self) -> f64 {
        self.actual / self.original_actual
    }
}

/// Random channel-pruning search on the 5-layer CNN family: draw random
/// sub-widths, keep the first candidate whose *estimated* energy is under
/// `budget_frac` of the original (paper: 50 %), preferring the least
/// pruned such candidate seen within `tries`.
pub fn prune_cnn5(
    dev: &mut Device,
    original: &[usize; 4],
    img: usize,
    batch: usize,
    budget_frac: f64,
    guidance: Guidance,
    tries: usize,
    iterations: usize,
    seed: u64,
) -> PruneOutcome {
    let orig_graph = zoo::cnn5(original, img, batch);
    let orig_actual = dev.run(&fuse(&lower(&orig_graph)), iterations).energy_per_iter();

    // §Perf: one memo cache across the whole candidate sweep — the few
    // cnn5 families are re-queried at overlapping widths on every try,
    // and cached values are bit-identical to fresh predictions.  The
    // cache is generation-stamped against the store, so it stays valid
    // even if the guiding Thor re-profiles mid-sweep.
    let mut cache = EstimateCache::new();
    let mut estimate = |g: &ModelGraph| -> f64 {
        match &guidance {
            Guidance::Thor(thor, device) => thor
                .estimate_cached(device, g, &mut cache)
                .map(|e| e.energy_per_iter)
                .unwrap_or(f64::INFINITY),
            Guidance::FlopsRatio { original_actual } => {
                original_actual * model_train_flops(g) / model_train_flops(&orig_graph)
            }
        }
    };

    let mut rng = Pcg64::new(seed);
    let mut best: Option<(Vec<usize>, f64, f64)> = None; // (channels, predicted, params score)
    for _ in 0..tries {
        let ch: Vec<usize> = original.iter().map(|&c| rng.range_usize(1, c)).collect();
        let g = zoo::cnn5(&[ch[0], ch[1], ch[2], ch[3]], img, batch);
        let pred = estimate(&g);
        if pred <= budget_frac * orig_actual {
            // prefer the largest surviving capacity under budget
            let capacity = g.total_params() as f64;
            if best.as_ref().map_or(true, |(_, _, c)| capacity > *c) {
                best = Some((ch, pred, capacity));
            }
        }
    }
    let (channels, predicted, _) = best.unwrap_or_else(|| {
        // fall back: smallest possible model
        (vec![1, 1, 1, 1], estimate(&zoo::cnn5(&[1, 1, 1, 1], img, batch)), 0.0)
    });
    let g = zoo::cnn5(&[channels[0], channels[1], channels[2], channels[3]], img, batch);
    let actual = dev.run(&fuse(&lower(&g)), iterations).energy_per_iter();
    PruneOutcome { channels, predicted, actual, original_actual: orig_actual }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simdevice::devices;
    use crate::thor::ThorConfig;

    #[test]
    fn thor_guided_lands_within_budget_flops_overshoots() {
        // Miniature Fig 13 on Xavier.
        let original = [16usize, 32, 64, 128];
        let mut dev = Device::new(devices::xavier(), 9);
        let mut thor = Thor::new(ThorConfig::quick());
        thor.profile_local(&mut dev, &zoo::cnn5(&original, 16, 10));

        let iters = 120;
        let t = prune_cnn5(
            &mut dev,
            &original,
            16,
            10,
            0.5,
            Guidance::Thor(&thor, "xavier"),
            60,
            iters,
            5,
        );
        let orig_actual = t.original_actual;
        let f = prune_cnn5(
            &mut dev,
            &original,
            16,
            10,
            0.5,
            Guidance::FlopsRatio { original_actual: orig_actual },
            60,
            iters,
            5,
        );
        // THOR stays within (or near) budget; FLOPs-ratio overshoots more.
        assert!(t.actual_ratio() < 0.62, "thor ratio {}", t.actual_ratio());
        assert!(
            f.actual_ratio() > t.actual_ratio(),
            "flops {} should overshoot thor {}",
            f.actual_ratio(),
            t.actual_ratio()
        );
    }

    #[test]
    fn pruned_channels_within_original() {
        let original = [8usize, 16, 32, 64];
        let mut dev = Device::new(devices::tx2(), 3);
        let out = prune_cnn5(
            &mut dev,
            &original,
            16,
            10,
            0.5,
            Guidance::FlopsRatio { original_actual: 1.0 },
            30,
            40,
            7,
        );
        for (c, o) in out.channels.iter().zip(&original) {
            assert!(*c >= 1 && c <= o);
        }
    }
}
