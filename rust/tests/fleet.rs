//! Integration tests for the decoupled fleet architecture over real
//! loopback sockets: the scheduler invariants (exactly-once resolution,
//! re-queue on worker death) promoted from `coordinator::scheduler`'s
//! unit/property level to a full leader + N `DeviceWorker` run, and the
//! scheduling-independence of the fitted store that `exp::fleet_exp`
//! (the `fleet1` experiment) relies on for byte-stable reports.
//!
//! All runs use deterministic per-job measurement seeds
//! (`DeviceWorker::with_per_job_seed` + `coordinator::job_seed`), which
//! makes the final `GpStore` a pure function of (reference, config, base
//! seed) — so a 3-worker fleet, a 3-worker fleet with a mid-stream
//! death, and a single worker must all produce byte-identical stores.
//!
//! CI runs this file under a 60-second timeout guard: any dead/live-lock
//! in the leader loop fails fast instead of hanging the suite.

use thor::coordinator::{DeviceWorker, FleetRun, FleetServer, FleetSpec};
use thor::model::{zoo, ModelGraph};
use thor::simdevice::{devices, Device};
use thor::thor::{Batch, ThorConfig};

const BASE_SEED: u64 = 42;

fn reference() -> ModelGraph {
    // Small cnn5: 5 families (out, in, 3 hidden), each needing at least
    // its 3–5 start-point jobs, so every worker sees several jobs.
    zoo::cnn5(&[8, 16, 32, 64], 16, 10)
}

/// Run a loopback fleet with `n_workers`.  `die_after` = `Some((w, k))`
/// makes client `w` drop its connection upon receiving job `k + 1`,
/// leaving that job in flight.
///
/// The acquisition batch is fixed at 3 for every worker count: the
/// probe sequence depends on the batch size (3 top-variance proposals
/// per GP round), never on the worker count, so stores stay comparable
/// across 1-, 2- and 3-worker runs.
fn run_fleet(n_workers: usize, die_after: Option<(usize, usize)>) -> FleetRun {
    let server = FleetServer::new(ThorConfig { batch: Batch::Fixed(3), ..ThorConfig::quick() });
    let bound = server.bind("127.0.0.1:0").expect("bind ephemeral loopback port");
    let addr = bound.local_addr().to_string();

    let mut handles = Vec::new();
    for w in 0..n_workers {
        let addr = addr.clone();
        let reference = reference();
        let limit = die_after.and_then(|(dw, k)| (dw == w).then_some(k));
        handles.push(std::thread::spawn(move || {
            let mut worker =
                DeviceWorker::new(Device::new(devices::xavier(), 100 + w as u64), &reference)
                    .with_per_job_seed(BASE_SEED);
            match limit {
                Some(k) => worker.run_limited(&addr, k),
                None => worker.run(&addr),
            }
        }));
    }

    let run = bound.serve(&reference(), n_workers).expect("fleet serve");
    for h in handles {
        let _ = h.join();
    }
    run
}

#[test]
fn worker_death_requeues_jobs_and_every_job_resolves_exactly_once() {
    let faulty = run_fleet(3, Some((2, 2)));

    // The dying worker received a job it never answered: that job must
    // have been re-queued...
    assert!(faulty.requeued >= 1, "no job was re-queued on worker death");
    // ...and every submitted job still resolved exactly once (the queue
    // drops duplicate/stale completions, so done == submitted means
    // exactly-once, not at-least-once).
    assert_eq!(
        faulty.jobs_done, faulty.jobs_submitted,
        "job(s) lost or double-counted after worker death"
    );
    assert_eq!(
        faulty.per_worker.iter().sum::<usize>(),
        faulty.jobs_done,
        "per-worker counts do not add up to the total"
    );
    assert_eq!(faulty.store.len(), 5, "store missing families after worker death");

    // The fitted store must be byte-identical to a run that never saw a
    // death (per-job seeds make measurements scheduling-independent, and
    // a re-measured re-queued job reproduces the lost measurement).
    let baseline = run_fleet(1, None);
    assert_eq!(
        faulty.store.to_json().to_string(),
        baseline.store.to_json().to_string(),
        "worker death changed the fitted store"
    );
}

#[test]
fn store_is_independent_of_worker_count_and_all_workers_contribute() {
    let one = run_fleet(1, None);
    let three = run_fleet(3, None);

    assert_eq!(
        one.store.to_json().to_string(),
        three.store.to_json().to_string(),
        "worker count changed the fitted store"
    );
    assert_eq!(one.jobs_submitted, three.jobs_submitted, "probe sequence diverged");
    assert_eq!(three.requeued, 0);
    // Family-affinity scheduling spreads the 5 families over 3 workers,
    // so every worker must have completed at least one job.
    assert_eq!(three.per_worker.len(), 3);
    assert!(
        three.per_worker.iter().all(|&n| n > 0),
        "idle worker in a healthy fleet: {:?}",
        three.per_worker
    );
}

#[test]
fn missing_device_class_fails_formation_with_a_descriptive_error() {
    // A heterogeneous serve where one requested class never says Hello
    // must be a hard error after the grace window — never a silently
    // class-less store (the pre-fix behavior was to proceed with the
    // partial fleet even when a whole class was absent).
    let server = FleetServer::new(ThorConfig { batch: Batch::Auto, ..ThorConfig::quick() });
    let bound = server.bind("127.0.0.1:0").expect("bind ephemeral loopback port");
    let addr = bound.local_addr().to_string();

    // Only the xavier worker shows up; tx2 never connects.
    let reference_x = reference();
    let handle = std::thread::spawn(move || {
        let mut worker =
            DeviceWorker::new(Device::new(devices::xavier(), 100), &reference_x)
                .with_class_seed(BASE_SEED);
        worker.run(&addr)
    });

    let spec = FleetSpec::mixed(&[("xavier", 1), ("tx2", 1)])
        .with_grace(std::time::Duration::from_millis(300));
    let err = match bound.serve_spec(&reference(), spec) {
        Ok(_) => panic!("serve must fail when a whole requested class is missing"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("tx2"), "error does not name the missing class: {msg}");
    assert!(
        msg.to_lowercase().contains("never said hello"),
        "error does not describe the formation failure: {msg}"
    );
    let _ = handle.join();
}

#[test]
fn hetero_fleet_worker_death_requeues_within_the_class() {
    // Mixed fleet, one tx2 worker dies mid-stream: its job must be
    // re-measured by the surviving tx2 worker, every job resolving
    // exactly once per class, and the run still completes all classes.
    let server = FleetServer::new(ThorConfig { batch: Batch::Auto, ..ThorConfig::quick() });
    let bound = server.bind("127.0.0.1:0").expect("bind ephemeral loopback port");
    let addr = bound.local_addr().to_string();
    let spec = FleetSpec::mixed(&[("xavier", 2), ("tx2", 2)]);

    let mut handles = Vec::new();
    for (i, class) in ["xavier", "xavier", "tx2", "tx2"].iter().enumerate() {
        let addr = addr.clone();
        let reference = reference();
        let profile = devices::by_name(class).expect("device class");
        // The last-connecting tx2 worker dies upon its 3rd job.  (Which
        // connection id it gets is racy; dying after a fixed job count
        // keeps the scenario valid either way.)
        let limit = (i == 3).then_some(2);
        handles.push(std::thread::spawn(move || {
            let mut worker = DeviceWorker::new(Device::new(profile, 100 + i as u64), &reference)
                .with_class_seed(BASE_SEED);
            match limit {
                Some(k) => worker.run_limited(&addr, k),
                None => worker.run(&addr),
            }
        }));
    }

    let run = bound.serve_spec(&reference(), spec).expect("hetero fleet serve");
    for h in handles {
        let _ = h.join();
    }
    assert!(run.requeued >= 1, "no job was re-queued on the tx2 worker death");
    assert_eq!(
        run.jobs_done, run.jobs_submitted,
        "job(s) lost or double-counted after worker death"
    );
    assert_eq!(run.store.len(), 10, "store missing families: 5 per class expected");
    for (class, n) in &run.per_class {
        assert!(*n > 0, "class {class} completed no jobs");
    }
    // The dying worker is tx2-class, so xavier's ledger is untouched:
    // per-class done == submitted holds for both (exactly-once), which
    // run.jobs_done == run.jobs_submitted plus the per_class sum checks.
    assert_eq!(
        run.per_class.iter().map(|(_, n)| n).sum::<usize>(),
        run.jobs_done,
        "per-class counts do not add up to the total"
    );
}

#[test]
fn healthy_fleet_per_worker_counts_are_deterministic() {
    // Affinity scheduling + hello gating make the per-worker job counts
    // (not just the store) a pure function of the config — this is what
    // lets the fleet1 experiment put them in a golden-checked report.
    let a = run_fleet(2, None);
    let b = run_fleet(2, None);
    assert_eq!(a.per_worker, b.per_worker, "per-worker counts not deterministic");
    assert_eq!(a.jobs_done, b.jobs_done);
}
