//! Integration tests for the decoupled fleet architecture over real
//! loopback sockets: the scheduler invariants (exactly-once resolution,
//! re-queue on worker death) promoted from `coordinator::scheduler`'s
//! unit/property level to a full leader + N `DeviceWorker` run, and the
//! scheduling-independence of the fitted store that `exp::fleet_exp`
//! (the `fleet1` experiment) relies on for byte-stable reports.
//!
//! All runs use deterministic per-job measurement seeds
//! (`DeviceWorker::with_per_job_seed` + `coordinator::job_seed`), which
//! makes the final `GpStore` a pure function of (reference, config, base
//! seed) — so a 3-worker fleet, a 3-worker fleet with a mid-stream
//! death, and a single worker must all produce byte-identical stores.
//!
//! The elasticity tests extend the same contract to worker *rejoin* (a
//! dead worker reconnects as a fresh id and serves the rest of the run)
//! and to leader *checkpoint/resume* (`thor::thor::checkpoint`): a
//! leader killed between absorbs is replaced by a successor that resumes
//! from its checkpoint, and the resumed final store must be
//! byte-identical to the uninterrupted run's — with no measurement job
//! ever re-issued for an already-absorbed point.
//!
//! The straggler tests cover the fault elasticity cannot see: a worker
//! that *hangs without disconnecting* (`coordinator::FaultPlan`).
//! Per-job deadlines (`FleetSpec::with_deadline`) detect the silence and
//! speculatively re-issue the held job to a live peer; duplicate
//! completions from a recovered straggler are deduped first-result-wins;
//! and per-job seeds keep every one of these stores byte-identical to
//! the healthy baseline.
//!
//! CI runs this file under a 120-second timeout guard: any dead/live-lock
//! in the leader loop fails fast instead of hanging the suite.

use std::time::{Duration, Instant};

use thor::coordinator::{
    reconnect_backoff, DeviceWorker, FaultPlan, FleetRun, FleetServer, FleetSpec, ServeOptions,
    Stall,
};
use thor::model::{zoo, ModelGraph};
use thor::simdevice::{devices, Device};
use thor::thor::{
    Batch, Checkpoint, Checkpointer, LocalMeasurer, MeasureError, MeasureRequest, Measurement,
    Measurer, ProfileOptions, Thor, ThorConfig,
};

const BASE_SEED: u64 = 42;

fn reference() -> ModelGraph {
    // Small cnn5: 5 families (out, in, 3 hidden), each needing at least
    // its 3–5 start-point jobs, so every worker sees several jobs.
    zoo::cnn5(&[8, 16, 32, 64], 16, 10)
}

/// Run a loopback fleet with `n_workers`.  `die_after` = `Some((w, k))`
/// makes client `w` drop its connection upon receiving job `k + 1`,
/// leaving that job in flight.
///
/// The acquisition batch is fixed at 3 for every worker count: the
/// probe sequence depends on the batch size (3 top-variance proposals
/// per GP round), never on the worker count, so stores stay comparable
/// across 1-, 2- and 3-worker runs.
fn run_fleet(n_workers: usize, die_after: Option<(usize, usize)>) -> FleetRun {
    let server = FleetServer::new(ThorConfig { batch: Batch::Fixed(3), ..ThorConfig::quick() });
    let bound = server.bind("127.0.0.1:0").expect("bind ephemeral loopback port");
    let addr = bound.local_addr().to_string();

    let mut handles = Vec::new();
    for w in 0..n_workers {
        let addr = addr.clone();
        let reference = reference();
        let limit = die_after.and_then(|(dw, k)| (dw == w).then_some(k));
        handles.push(std::thread::spawn(move || {
            let mut worker =
                DeviceWorker::new(Device::new(devices::xavier(), 100 + w as u64), &reference)
                    .with_per_job_seed(BASE_SEED);
            match limit {
                Some(k) => worker.run_limited(&addr, k),
                None => worker.run(&addr),
            }
        }));
    }

    let run = bound.serve(&reference(), n_workers).expect("fleet serve");
    for h in handles {
        let _ = h.join();
    }
    run
}

/// Run a 2-worker loopback fleet where worker `faulty` carries `plan`
/// and the leader enforces a `deadline_ms` per-job straggler deadline.
fn run_straggler_fleet(faulty: usize, plan: FaultPlan, deadline_ms: u64) -> FleetRun {
    let server = FleetServer::new(ThorConfig { batch: Batch::Fixed(3), ..ThorConfig::quick() });
    let bound = server.bind("127.0.0.1:0").expect("bind ephemeral loopback port");
    let addr = bound.local_addr().to_string();

    let mut handles = Vec::new();
    for w in 0..2usize {
        let addr = addr.clone();
        let reference = reference();
        let plan = if w == faulty { plan.clone() } else { FaultPlan::default() };
        handles.push(std::thread::spawn(move || {
            let mut worker =
                DeviceWorker::new(Device::new(devices::xavier(), 100 + w as u64), &reference)
                    .with_per_job_seed(BASE_SEED)
                    .with_faults(plan);
            worker.run(&addr)
        }));
    }

    let spec =
        FleetSpec::untyped(2).with_deadline(Duration::from_millis(deadline_ms));
    let run = bound.serve_spec(&reference(), spec).expect("straggler fleet serve");
    for h in handles {
        let _ = h.join();
    }
    run
}

#[test]
fn hung_worker_never_stalls_a_batch_past_its_deadline() {
    // Worker 1 completes one job then hangs — connected, reading,
    // silent.  No Disconnected event ever fires, so only the deadline
    // machinery can recover its held job; the run must complete with
    // the job speculatively re-issued to worker 0, and the store must
    // show no trace of any of it.
    let run = run_straggler_fleet(1, FaultPlan::hang_after(1), 300);
    assert!(run.speculated >= 1, "the hang never forced a speculative re-issue");
    assert_eq!(run.requeued, 0, "a hang must not look like a disconnect");
    assert_eq!(run.jobs_done, run.jobs_submitted, "job(s) lost or double-counted");
    assert_eq!(run.store.len(), 5, "store missing families after the hang");
    let baseline = run_fleet(1, None);
    assert_eq!(
        run.store.to_json().to_string(),
        baseline.store.to_json().to_string(),
        "the hung worker changed the fitted store"
    );
}

#[test]
fn duplicate_completions_from_a_recovered_straggler_are_deduped() {
    // Worker 1 stalls 900ms on its second job — far past the 250ms
    // deadline — then *recovers and answers*.  By then the job has been
    // speculatively re-issued, so the leader sees two completions; the
    // queue takes the first and drops the duplicate, and per-job seeds
    // make both results bitwise identical anyway.
    let run =
        run_straggler_fleet(1, FaultPlan::stall_after(1, Stall::Recover(Duration::from_millis(900))), 250);
    assert!(run.speculated >= 1, "the stall never forced a speculative re-issue");
    assert_eq!(
        run.jobs_done, run.jobs_submitted,
        "duplicate completion double-counted or job lost"
    );
    let baseline = run_fleet(1, None);
    assert_eq!(
        run.store.to_json().to_string(),
        baseline.store.to_json().to_string(),
        "the recovered straggler changed the fitted store"
    );
}

#[test]
fn reconnect_backoff_spends_its_budget_against_a_dead_leader() {
    // Bind then immediately drop a listener: the port refuses connects.
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind probe port");
    let addr = listener.local_addr().unwrap().to_string();
    drop(listener);

    let reference = reference();
    let mut worker = DeviceWorker::new(Device::new(devices::xavier(), 100), &reference)
        .with_per_job_seed(BASE_SEED);
    let t0 = Instant::now();
    let done = worker.run_reconnecting(&addr, 2, 7);
    assert_eq!(done, 0, "no leader, no jobs");
    // Two inter-attempt waits, deterministic from the seed: the loop
    // must actually have backed off, not hot-spun.
    let floor = reconnect_backoff(7, 0) + reconnect_backoff(7, 1);
    assert!(
        t0.elapsed() >= floor,
        "reconnect loop did not back off: {:?} < {floor:?}",
        t0.elapsed()
    );
}

#[test]
fn reconnecting_worker_finishes_a_healthy_serve_on_shutdown() {
    // Against a healthy leader the reconnect loop must end on Shutdown
    // without spending any reconnect budget, reporting the full job
    // count — and the store is the usual pure function of the config.
    let server = FleetServer::new(ThorConfig { batch: Batch::Fixed(3), ..ThorConfig::quick() });
    let bound = server.bind("127.0.0.1:0").expect("bind ephemeral loopback port");
    let addr = bound.local_addr().to_string();
    let reference_w = reference();
    let handle = std::thread::spawn(move || {
        DeviceWorker::new(Device::new(devices::xavier(), 100), &reference_w)
            .with_per_job_seed(BASE_SEED)
            .run_reconnecting(&addr, 5, 11)
    });
    let run = bound.serve(&reference(), 1).expect("fleet serve");
    let done = handle.join().expect("worker thread");
    assert_eq!(done, run.jobs_done, "Shutdown must end the loop with the full job count");
    let baseline = run_fleet(1, None);
    assert_eq!(
        run.store.to_json().to_string(),
        baseline.store.to_json().to_string(),
        "the reconnecting worker changed the fitted store"
    );
}

#[test]
fn worker_death_requeues_jobs_and_every_job_resolves_exactly_once() {
    let faulty = run_fleet(3, Some((2, 2)));

    // The dying worker received a job it never answered: that job must
    // have been re-queued...
    assert!(faulty.requeued >= 1, "no job was re-queued on worker death");
    // ...and every submitted job still resolved exactly once (the queue
    // drops duplicate/stale completions, so done == submitted means
    // exactly-once, not at-least-once).
    assert_eq!(
        faulty.jobs_done, faulty.jobs_submitted,
        "job(s) lost or double-counted after worker death"
    );
    assert_eq!(
        faulty.per_worker.iter().sum::<usize>(),
        faulty.jobs_done,
        "per-worker counts do not add up to the total"
    );
    assert_eq!(faulty.store.len(), 5, "store missing families after worker death");

    // The fitted store must be byte-identical to a run that never saw a
    // death (per-job seeds make measurements scheduling-independent, and
    // a re-measured re-queued job reproduces the lost measurement).
    let baseline = run_fleet(1, None);
    assert_eq!(
        faulty.store.to_json().to_string(),
        baseline.store.to_json().to_string(),
        "worker death changed the fitted store"
    );
}

#[test]
fn store_is_independent_of_worker_count_and_all_workers_contribute() {
    let one = run_fleet(1, None);
    let three = run_fleet(3, None);

    assert_eq!(
        one.store.to_json().to_string(),
        three.store.to_json().to_string(),
        "worker count changed the fitted store"
    );
    assert_eq!(one.jobs_submitted, three.jobs_submitted, "probe sequence diverged");
    assert_eq!(three.requeued, 0);
    // Family-affinity scheduling spreads the 5 families over 3 workers,
    // so every worker must have completed at least one job.
    assert_eq!(three.per_worker.len(), 3);
    assert!(
        three.per_worker.iter().all(|&n| n > 0),
        "idle worker in a healthy fleet: {:?}",
        three.per_worker
    );
}

#[test]
fn missing_device_class_fails_formation_with_a_descriptive_error() {
    // A heterogeneous serve where one requested class never says Hello
    // must be a hard error after the grace window — never a silently
    // class-less store (the pre-fix behavior was to proceed with the
    // partial fleet even when a whole class was absent).
    let server = FleetServer::new(ThorConfig { batch: Batch::Auto, ..ThorConfig::quick() });
    let bound = server.bind("127.0.0.1:0").expect("bind ephemeral loopback port");
    let addr = bound.local_addr().to_string();

    // Only the xavier worker shows up; tx2 never connects.
    let reference_x = reference();
    let handle = std::thread::spawn(move || {
        let mut worker =
            DeviceWorker::new(Device::new(devices::xavier(), 100), &reference_x)
                .with_class_seed(BASE_SEED);
        worker.run(&addr)
    });

    let spec = FleetSpec::mixed(&[("xavier", 1), ("tx2", 1)])
        .with_grace(std::time::Duration::from_millis(300));
    let err = match bound.serve_spec(&reference(), spec) {
        Ok(_) => panic!("serve must fail when a whole requested class is missing"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("tx2"), "error does not name the missing class: {msg}");
    assert!(
        msg.to_lowercase().contains("never said hello"),
        "error does not describe the formation failure: {msg}"
    );
    let _ = handle.join();
}

#[test]
fn hetero_fleet_worker_death_requeues_within_the_class() {
    // Mixed fleet, one tx2 worker dies mid-stream: its job must be
    // re-measured by the surviving tx2 worker, every job resolving
    // exactly once per class, and the run still completes all classes.
    let server = FleetServer::new(ThorConfig { batch: Batch::Auto, ..ThorConfig::quick() });
    let bound = server.bind("127.0.0.1:0").expect("bind ephemeral loopback port");
    let addr = bound.local_addr().to_string();
    let spec = FleetSpec::mixed(&[("xavier", 2), ("tx2", 2)]);

    let mut handles = Vec::new();
    for (i, class) in ["xavier", "xavier", "tx2", "tx2"].iter().enumerate() {
        let addr = addr.clone();
        let reference = reference();
        let profile = devices::by_name(class).expect("device class");
        // The last-connecting tx2 worker dies upon its 3rd job.  (Which
        // connection id it gets is racy; dying after a fixed job count
        // keeps the scenario valid either way.)
        let limit = (i == 3).then_some(2);
        handles.push(std::thread::spawn(move || {
            let mut worker = DeviceWorker::new(Device::new(profile, 100 + i as u64), &reference)
                .with_class_seed(BASE_SEED);
            match limit {
                Some(k) => worker.run_limited(&addr, k),
                None => worker.run(&addr),
            }
        }));
    }

    let run = bound.serve_spec(&reference(), spec).expect("hetero fleet serve");
    for h in handles {
        let _ = h.join();
    }
    assert!(run.requeued >= 1, "no job was re-queued on the tx2 worker death");
    assert_eq!(
        run.jobs_done, run.jobs_submitted,
        "job(s) lost or double-counted after worker death"
    );
    assert_eq!(run.store.len(), 10, "store missing families: 5 per class expected");
    for (class, n) in &run.per_class {
        assert!(*n > 0, "class {class} completed no jobs");
    }
    // The dying worker is tx2-class, so xavier's ledger is untouched:
    // per-class done == submitted holds for both (exactly-once), which
    // run.jobs_done == run.jobs_submitted plus the per_class sum checks.
    assert_eq!(
        run.per_class.iter().map(|(_, n)| n).sum::<usize>(),
        run.jobs_done,
        "per-class counts do not add up to the total"
    );
}

#[test]
fn dead_worker_rejoins_as_a_fresh_id_and_serves_the_rest_of_the_run() {
    // Worker 1 completes one job, dies with its second in flight, then
    // reconnects — the leader files the re-Hello as connection id 2 and
    // folds it back into the class, so the ledger grows a third slot
    // and the rejoined incarnation finishes real work.
    let server = FleetServer::new(ThorConfig { batch: Batch::Fixed(3), ..ThorConfig::quick() });
    let bound = server.bind("127.0.0.1:0").expect("bind ephemeral loopback port");
    let addr = bound.local_addr().to_string();

    let mut handles = Vec::new();
    for w in 0..2u64 {
        let addr = addr.clone();
        let reference = reference();
        handles.push(std::thread::spawn(move || {
            let mut worker = DeviceWorker::new(Device::new(devices::xavier(), 100 + w), &reference)
                .with_per_job_seed(BASE_SEED);
            if w == 0 {
                worker.run(&addr).unwrap_or(0)
            } else {
                worker.run_phases(&[(addr.clone(), Some(1)), (addr, None)])
            }
        }));
    }
    let run = bound.serve(&reference(), 2).expect("fleet serve");
    for h in handles {
        let _ = h.join();
    }

    assert!(run.requeued >= 1, "the death left no job to re-queue");
    assert_eq!(run.jobs_done, run.jobs_submitted, "job(s) lost or double-counted");
    // Two founders + one rejoined incarnation = three ledger slots; the
    // rejoined id must have contributed (batch affinity round-robins
    // over the live ids {0, 2} for the rest of the run).
    assert_eq!(run.per_worker.len(), 3, "rejoin did not grow the ledger: {:?}", run.per_worker);
    assert!(run.per_worker[2] > 0, "rejoined worker never served a job: {:?}", run.per_worker);
    assert_eq!(
        run.per_worker.iter().sum::<usize>(),
        run.jobs_done,
        "per-worker counts do not add up across incarnations"
    );
    // Exactly-once per class held across the death and the rejoin, and
    // the store is still the pure function of the config.
    let baseline = run_fleet(1, None);
    assert_eq!(
        run.store.to_json().to_string(),
        baseline.store.to_json().to_string(),
        "death + rejoin changed the fitted store"
    );
}

/// A [`LocalMeasurer`] wrapper that logs every measured request and can
/// fail on a chosen call — the in-process leader-kill fault: the error
/// fires *before* the batch is measured or logged, so the log holds
/// exactly the absorbed work.
struct Recording {
    inner: LocalMeasurer<'static>,
    log: Vec<MeasureRequest>,
    fail_after: Option<usize>,
    calls: usize,
}

impl Recording {
    fn new(reference: &ModelGraph, fail_after: Option<usize>) -> Self {
        Self {
            inner: LocalMeasurer::per_job(devices::xavier(), BASE_SEED, reference),
            log: Vec::new(),
            fail_after,
            calls: 0,
        }
    }
}

impl Measurer for Recording {
    fn devices(&self) -> Vec<String> {
        self.inner.devices()
    }

    fn measure_batch(&mut self, reqs: &[MeasureRequest]) -> Result<Vec<Measurement>, MeasureError> {
        self.calls += 1;
        if self.fail_after.map_or(false, |k| self.calls > k) {
            return Err(MeasureError("injected leader death".into()));
        }
        self.log.extend(reqs.iter().cloned());
        self.inner.measure_batch(reqs)
    }

    fn occupancy(&self, device: &str) -> usize {
        self.inner.occupancy(device)
    }
}

#[test]
fn checkpoint_resume_is_byte_identical_and_never_remeasures_absorbed_points() {
    let cfg = ThorConfig { batch: Batch::Fixed(2), ..ThorConfig::quick() };
    let reference = reference();

    // The uninterrupted run: final store S* and request log R*.
    let mut star = Thor::new(cfg);
    let mut m_star = Recording::new(&reference, None);
    star.profile(&mut m_star, &reference).expect("uninterrupted profile");
    let store_star = star.store.to_json().to_string();

    // The doomed run: checkpoint after every absorbed batch, die on the
    // 4th — between absorbs, the durability point.
    let path =
        std::env::temp_dir().join(format!("thor_fleet_resume_{}.json", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let mut ck_writer = Checkpointer::new(&path, 1);
    let mut doomed = Thor::new(cfg);
    let mut m1 = Recording::new(&reference, Some(3));
    let died = doomed
        .profile_with(
            &mut m1,
            &reference,
            ProfileOptions { checkpointer: Some(&mut ck_writer), ..Default::default() },
        )
        .is_err();
    assert!(died, "fault injection never fired");
    assert_eq!(ck_writer.writes, 3, "one checkpoint per absorbed batch");

    // The successor: resume from the checkpoint and finish.
    let ck = Checkpoint::load(&path).expect("read checkpoint").expect("checkpoint written");
    assert!(!ck.inflight.is_empty(), "no in-flight machine to resume");
    let mut resumed = Thor::new(cfg);
    resumed.store = ck.store;
    let mut m2 = Recording::new(&reference, None);
    resumed
        .profile_with(
            &mut m2,
            &reference,
            ProfileOptions { resume: ck.inflight, ..Default::default() },
        )
        .expect("resumed profile");

    assert_eq!(
        resumed.store.to_json().to_string(),
        store_star,
        "resumed store diverged from the uninterrupted run"
    );

    // No measurement job is ever re-issued for an absorbed point: the
    // doomed log followed by the resumed log is *exactly* the
    // uninterrupted log, element for element.  (The injected failure
    // fires before the 4th batch is measured, so that batch's requests
    // appear once — re-proposed identically by the resumed machine.)
    let mut joined = m1.log.clone();
    joined.extend(m2.log.iter().cloned());
    assert_eq!(joined, m_star.log, "resume re-measured absorbed points or skipped work");

    // Atomic writes left no torn tmp file behind.
    let tmp = path.with_file_name(format!(
        "{}.tmp",
        path.file_name().unwrap().to_string_lossy()
    ));
    assert!(!tmp.exists(), "atomic checkpoint write leaked {tmp:?}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn killed_leader_is_resumed_by_a_successor_over_real_sockets() {
    // Socket-level version of the resume contract: leader A checkpoints
    // and is killed after 3 joint batches; the workers fall through to
    // leader B, which resumes from A's checkpoint.  The resumed store
    // must be byte-identical to a healthy fleet's, on strictly fewer
    // submitted jobs (the checkpointed work is never re-measured).
    let cfg = ThorConfig { batch: Batch::Fixed(3), ..ThorConfig::quick() };
    let bound_a = FleetServer::new(cfg).bind("127.0.0.1:0").expect("bind leader A");
    let bound_b = FleetServer::new(cfg).bind("127.0.0.1:0").expect("bind leader B");
    let addr_a = bound_a.local_addr().to_string();
    let addr_b = bound_b.local_addr().to_string();

    let mut handles = Vec::new();
    for w in 0..2u64 {
        let reference = reference();
        let phases = vec![(addr_a.clone(), None), (addr_b.clone(), None)];
        handles.push(std::thread::spawn(move || {
            DeviceWorker::new(Device::new(devices::xavier(), 100 + w), &reference)
                .with_per_job_seed(BASE_SEED)
                .run_phases(&phases)
        }));
    }

    let path =
        std::env::temp_dir().join(format!("thor_fleet_handover_{}.json", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let mut ck_writer = Checkpointer::new(&path, 1);
    let died = bound_a
        .serve_spec_with(
            &reference(),
            FleetSpec::untyped(2),
            ServeOptions {
                resume: None,
                checkpointer: Some(&mut ck_writer),
                abort_after_rounds: Some(3),
            },
        )
        .is_err();
    assert!(died, "leader A's fault injection never fired");

    let ck = Checkpoint::load(&path).expect("read checkpoint").expect("checkpoint written");
    let resumed = bound_b
        .serve_spec_with(
            &reference(),
            FleetSpec::untyped(2),
            ServeOptions { resume: Some(ck), ..Default::default() },
        )
        .expect("resumed fleet serve");
    for h in handles {
        let _ = h.join();
    }
    let _ = std::fs::remove_file(&path);

    let baseline = run_fleet(2, None);
    assert_eq!(
        resumed.store.to_json().to_string(),
        baseline.store.to_json().to_string(),
        "leader handover changed the fitted store"
    );
    assert!(
        resumed.jobs_submitted < baseline.jobs_submitted,
        "resume re-submitted checkpointed work: {} vs {} jobs",
        resumed.jobs_submitted,
        baseline.jobs_submitted
    );
    assert_eq!(resumed.jobs_done, resumed.jobs_submitted);
    assert_eq!(resumed.requeued, 0, "no deaths were scheduled on leader B");
}

#[test]
fn healthy_fleet_per_worker_counts_are_deterministic() {
    // Affinity scheduling + hello gating make the per-worker job counts
    // (not just the store) a pure function of the config — this is what
    // lets the fleet1 experiment put them in a golden-checked report.
    let a = run_fleet(2, None);
    let b = run_fleet(2, None);
    assert_eq!(a.per_worker, b.per_worker, "per-worker counts not deterministic");
    assert_eq!(a.jobs_done, b.jobs_done);
}
