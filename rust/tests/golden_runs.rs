//! Golden-run regression harness + determinism check.
//!
//! Every registered experiment runs in quick mode at a fixed suite seed
//! through the multi-threaded runner, and its serialized `ExpReport` is
//! diffed byte-for-byte against `tests/golden/<id>.json`.
//!
//! Blessing policy (no silent self-blessing in CI):
//!
//! * `UPDATE_GOLDENS=1` — rewrite every golden; commit and review the
//!   diff (it IS the paper's numbers).
//! * golden missing, `GOLDEN_STRICT` unset — written once as a local
//!   bootstrap (toolchain-less build environments can't pre-generate
//!   them), with a loud reminder to commit.
//! * golden missing, `GOLDEN_STRICT=1` (exported by CI) — hard failure:
//!   a registered experiment without a committed golden is untested.
//! * golden stale (mismatch) — hard failure, always.
//!
//! The determinism test runs the full quick suite at several thread
//! counts and asserts byte-identical suite JSON — catching thread-order,
//! subtask fan-out and map-iteration nondeterminism anywhere in the
//! experiment layer.

use std::fs;
use std::path::PathBuf;

use thor::exp::{registry, Runner};

/// Fixed suite seed for goldens (matches the CLI default of
/// `thor exp --all --quick --json`).
const GOLDEN_SEED: u64 = 2025;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden")
}

/// First byte index where `a` and `b` differ, with a context window for
/// the assertion message (byte-sliced throughout, so the window is
/// positioned correctly even with multi-byte characters in titles).
fn first_divergence(a: &str, b: &str) -> String {
    let i = a.bytes().zip(b.bytes()).position(|(x, y)| x != y).unwrap_or(a.len().min(b.len()));
    let window = |s: &str| -> String {
        let lo = i.saturating_sub(60);
        let hi = (lo + 140).min(s.len());
        String::from_utf8_lossy(&s.as_bytes()[lo.min(s.len())..hi]).into_owned()
    };
    format!("first divergence at byte {i}:\n  got:  …{}…\n  want: …{}…", window(a), window(b))
}

#[test]
fn golden_quick_suite_matches_committed_reports() {
    let suite = Runner::new(2).run(registry::registry(), true, GOLDEN_SEED);
    let update = std::env::var("UPDATE_GOLDENS").map(|v| v == "1").unwrap_or(false);
    let strict = std::env::var("GOLDEN_STRICT").map(|v| v == "1").unwrap_or(false);
    fs::create_dir_all(golden_dir()).unwrap();

    let mut blessed = Vec::new();
    let mut missing = Vec::new();
    let mut mismatches = Vec::new();
    for rep in &suite.reports {
        assert!(
            rep.error.is_none(),
            "experiment {} panicked: {}",
            rep.id,
            rep.error.as_deref().unwrap_or("")
        );
        let path = golden_dir().join(format!("{}.json", rep.id));
        let got = rep.to_json().to_string();
        if update {
            fs::write(&path, &got).unwrap_or_else(|e| panic!("writing {path:?}: {e}"));
            blessed.push(rep.id.clone());
            continue;
        }
        if !path.exists() {
            if strict {
                missing.push(rep.id.clone());
            } else {
                // local bootstrap only — CI (GOLDEN_STRICT=1) refuses
                fs::write(&path, &got).unwrap_or_else(|e| panic!("writing {path:?}: {e}"));
                blessed.push(rep.id.clone());
            }
            continue;
        }
        let want = fs::read_to_string(&path).unwrap_or_else(|e| panic!("reading {path:?}: {e}"));
        if got != want {
            mismatches.push(format!("{}: {}", rep.id, first_divergence(&got, &want)));
        }
    }
    if !blessed.is_empty() {
        eprintln!(
            "blessed {} golden file(s) under {:?} — COMMIT THEM, CI fails on missing \
             goldens: {blessed:?}",
            blessed.len(),
            golden_dir()
        );
    }
    assert!(
        missing.is_empty(),
        "{} golden file(s) missing under strict mode — generate with \
         `UPDATE_GOLDENS=1 cargo test --test golden_runs` and commit: {missing:?}",
        missing.len()
    );
    assert!(
        mismatches.is_empty(),
        "{} golden mismatch(es) — if the change is intentional, regen with \
         `UPDATE_GOLDENS=1 cargo test --test golden_runs` and commit:\n{}",
        mismatches.len(),
        mismatches.join("\n")
    );

    // A golden that matches no registered experiment is a rename/removal
    // that silently escaped regression coverage — fail loudly.
    let known: Vec<String> = suite.reports.iter().map(|r| format!("{}.json", r.id)).collect();
    for entry in fs::read_dir(golden_dir()).unwrap() {
        let name = entry.unwrap().file_name().to_string_lossy().into_owned();
        if name.ends_with(".json") {
            assert!(
                known.contains(&name),
                "stale golden {name} matches no registered experiment — \
                 delete it (or restore the experiment id)"
            );
        }
    }
}

#[test]
fn quick_suite_json_is_byte_identical_across_runs_and_thread_counts() {
    // 2 vs 4 threads over the whole registry — with subtask fan-out this
    // also shuffles which worker runs which fig8/fig13 cell.
    let a = Runner::new(2).run(registry::registry(), true, 7).to_json().to_string();
    let b = Runner::new(4).run(registry::registry(), true, 7).to_json().to_string();
    assert!(
        a == b,
        "suite JSON differs between identical-seed runs; {}",
        first_divergence(&a, &b)
    );
}
