//! Backend-equivalence property: the measurement backend must be
//! invisible in the fitted artifact.  One pipeline
//! (`thor::pipeline::Thor::profile`) drives every backend, and the
//! determinism contract (per-request measurement seeds, leader-side
//! acquisition + fitting, no wall-clock in the store) makes the
//! resulting `GpStore` a pure function of (reference, config, base
//! seed).  Here that is asserted end to end over real loopback TCP:
//!
//! * `LocalMeasurer::per_job` vs a 1-worker fleet vs a 3-worker fleet —
//!   byte-identical store JSON (extends PR 2's fleet-only determinism
//!   test to the full active-learning loop across backends);
//! * the **heterogeneous single-leader** fleet (3 classes × 2 workers,
//!   one `serve_spec`) vs per-class `LocalMeasurer::per_job` stores
//!   merged into one `GpStore` — byte-identical JSON at `Batch::Auto`
//!   *and* `Batch::Fixed(1)` (class-scoped scheduling, per-class
//!   `class_seed` derivation, and interleaved acquisition must all be
//!   invisible in the artifact);
//! * the batch-size-1 ≡ pre-refactor-scalar-loop equivalence lives next
//!   to the loop itself (`thor::fit` test
//!   `batch_size_1_is_bit_identical_to_prerefactor_scalar_loop`).
//!
//! CI runs this file under a 120-second timeout guard next to the fleet
//! tests.

use thor::coordinator::{class_seed, DeviceWorker, FleetServer, FleetSpec};
use thor::model::{zoo, ModelGraph};
use thor::simdevice::{devices, Device};
use thor::thor::store::GpStore;
use thor::thor::{Batch, LocalMeasurer, Thor, ThorConfig};

const BASE_SEED: u64 = 42;
const BATCH: usize = 3;

/// Device classes of the heterogeneous fleet, 2 workers each.
const CLASSES: [&str; 3] = ["xavier", "tx2", "server"];
const PER_CLASS: usize = 2;

fn reference() -> ModelGraph {
    // Small cnn5: 5 families (out, in, 3 hidden).
    zoo::cnn5(&[8, 16, 32, 64], 16, 10)
}

fn cfg() -> ThorConfig {
    ThorConfig { batch: Batch::Fixed(BATCH), ..ThorConfig::quick() }
}

/// Store JSON from the in-process per-job-seeded backend.
fn local_store_json() -> String {
    let mut thor = Thor::new(cfg());
    let mut m = LocalMeasurer::per_job(devices::xavier(), BASE_SEED, &reference());
    thor.profile(&mut m, &reference()).expect("local profile");
    thor.store.to_json().to_string()
}

/// Store JSON from a loopback fleet with `n_workers` TCP workers.
fn fleet_store_json(n_workers: usize) -> String {
    let server = FleetServer::new(cfg());
    let bound = server.bind("127.0.0.1:0").expect("bind ephemeral loopback port");
    let addr = bound.local_addr().to_string();

    let mut handles = Vec::new();
    for w in 0..n_workers {
        let addr = addr.clone();
        let reference = reference();
        handles.push(std::thread::spawn(move || {
            let mut worker =
                DeviceWorker::new(Device::new(devices::xavier(), 100 + w as u64), &reference)
                    .with_per_job_seed(BASE_SEED);
            worker.run(&addr)
        }));
    }

    let run = bound.serve(&reference(), n_workers).expect("fleet serve");
    for h in handles {
        let _ = h.join();
    }
    run.store.to_json().to_string()
}

/// Store JSON from ONE leader serving the mixed fleet (2 workers per
/// class), class-derived per-job seeds, in one `serve_spec`.
fn hetero_fleet_store_json(batch: Batch) -> String {
    let server = FleetServer::new(ThorConfig { batch, ..ThorConfig::quick() });
    let bound = server.bind("127.0.0.1:0").expect("bind ephemeral loopback port");
    let addr = bound.local_addr().to_string();
    let spec = FleetSpec::mixed(&CLASSES.map(|c| (c, PER_CLASS)));

    let mut handles = Vec::new();
    for (ci, class) in CLASSES.iter().enumerate() {
        for w in 0..PER_CLASS {
            let addr = addr.clone();
            let reference = reference();
            let profile = devices::by_name(class).expect("device class");
            let dev_seed = 100 + (ci * PER_CLASS + w) as u64;
            handles.push(std::thread::spawn(move || {
                let mut worker = DeviceWorker::new(Device::new(profile, dev_seed), &reference)
                    .with_class_seed(BASE_SEED);
                worker.run(&addr)
            }));
        }
    }

    let run = bound.serve_spec(&reference(), spec).expect("heterogeneous fleet serve");
    for h in handles {
        let _ = h.join();
    }
    run.store.to_json().to_string()
}

/// Per-class `LocalMeasurer::per_job` stores (class-derived seeds, the
/// effective per-class batch) merged into one `GpStore` — the oracle
/// the heterogeneous fleet must reproduce byte-for-byte.
fn merged_per_class_local_store_json(batch: Batch) -> String {
    let mut merged = GpStore::new();
    for class in CLASSES {
        let profile = devices::by_name(class).expect("device class");
        // Auto sizes each round from the class's live worker count,
        // which a healthy 2-worker class holds at PER_CLASS all run.
        let eff = match batch {
            Batch::Auto => Batch::Fixed(PER_CLASS),
            b => b,
        };
        let mut thor = Thor::new(ThorConfig { batch: eff, ..ThorConfig::quick() });
        let mut m =
            LocalMeasurer::per_job(profile, class_seed(BASE_SEED, class), &reference());
        thor.profile(&mut m, &reference()).expect("local profile");
        merged.merge(thor.store);
    }
    merged.to_json().to_string()
}

#[test]
fn local_and_fleet_stores_are_byte_identical_at_1_and_3_workers() {
    let local = local_store_json();
    assert!(!local.is_empty() && local.contains("xavier"), "local store looks empty");
    let fleet1 = fleet_store_json(1);
    assert_eq!(
        local, fleet1,
        "1-worker fleet store diverged from the local per-job backend"
    );
    let fleet3 = fleet_store_json(3);
    assert_eq!(
        local, fleet3,
        "3-worker fleet store diverged from the local per-job backend"
    );
}

#[test]
fn hetero_fleet_store_is_byte_identical_to_merged_per_class_local_stores() {
    // Occupancy-adaptive batching: every class's rounds sized by its
    // own 2 live workers.
    let fleet_auto = hetero_fleet_store_json(Batch::Auto);
    for c in CLASSES {
        assert!(fleet_auto.contains(c), "heterogeneous store is missing class {c}");
    }
    let local_auto = merged_per_class_local_store_json(Batch::Auto);
    assert_eq!(
        fleet_auto, local_auto,
        "heterogeneous fleet store (batch=auto) diverged from merged per-class local stores"
    );

    // Fixed batch 1: the sequential acquisition stream per class.
    let fleet_b1 = hetero_fleet_store_json(Batch::Fixed(1));
    let local_b1 = merged_per_class_local_store_json(Batch::Fixed(1));
    assert_eq!(
        fleet_b1, local_b1,
        "heterogeneous fleet store (batch=1) diverged from merged per-class local stores"
    );
    assert_ne!(
        fleet_auto, fleet_b1,
        "auto (k=2) and batch=1 acquisition streams should differ — suspicious equality"
    );
}

#[test]
fn hetero_fleet_store_matches_one_shot_multi_class_local_backend() {
    // The in-process multi-class backend (per-class seeded device map)
    // profiled in ONE pipeline run is the third face of the same
    // artifact.
    let mut thor = Thor::new(ThorConfig { batch: Batch::Fixed(PER_CLASS), ..ThorConfig::quick() });
    let profiles = CLASSES.map(|c| devices::by_name(c).expect("device class")).to_vec();
    let mut m = LocalMeasurer::per_job_fleet(profiles, BASE_SEED, &reference());
    thor.profile(&mut m, &reference()).expect("multi-class local profile");
    assert_eq!(
        thor.store.to_json().to_string(),
        hetero_fleet_store_json(Batch::Auto),
        "multi-class LocalMeasurer diverged from the heterogeneous fleet"
    );
}
