//! Backend-equivalence property: the measurement backend must be
//! invisible in the fitted artifact.  One pipeline
//! (`thor::pipeline::Thor::profile`) drives every backend, and the
//! determinism contract (per-request measurement seeds, leader-side
//! acquisition + fitting, no wall-clock in the store) makes the
//! resulting `GpStore` a pure function of (reference, config, base
//! seed).  Here that is asserted end to end over real loopback TCP:
//!
//! * `LocalMeasurer::per_job` vs a 1-worker fleet vs a 3-worker fleet —
//!   byte-identical store JSON (extends PR 2's fleet-only determinism
//!   test to the full active-learning loop across backends);
//! * the batch-size-1 ≡ pre-refactor-scalar-loop equivalence lives next
//!   to the loop itself (`thor::fit` test
//!   `batch_size_1_is_bit_identical_to_prerefactor_scalar_loop`).
//!
//! CI runs this file under a 120-second timeout guard next to the fleet
//! tests.

use thor::coordinator::{DeviceWorker, FleetServer};
use thor::model::{zoo, ModelGraph};
use thor::simdevice::{devices, Device};
use thor::thor::{LocalMeasurer, Thor, ThorConfig};

const BASE_SEED: u64 = 42;
const BATCH: usize = 3;

fn reference() -> ModelGraph {
    // Small cnn5: 5 families (out, in, 3 hidden).
    zoo::cnn5(&[8, 16, 32, 64], 16, 10)
}

fn cfg() -> ThorConfig {
    ThorConfig { batch: BATCH, ..ThorConfig::quick() }
}

/// Store JSON from the in-process per-job-seeded backend.
fn local_store_json() -> String {
    let mut thor = Thor::new(cfg());
    let mut m = LocalMeasurer::per_job(devices::xavier(), BASE_SEED, &reference());
    thor.profile(&mut m, &reference()).expect("local profile");
    thor.store.to_json().to_string()
}

/// Store JSON from a loopback fleet with `n_workers` TCP workers.
fn fleet_store_json(n_workers: usize) -> String {
    let server = FleetServer::new(cfg());
    let bound = server.bind("127.0.0.1:0").expect("bind ephemeral loopback port");
    let addr = bound.local_addr().to_string();

    let mut handles = Vec::new();
    for w in 0..n_workers {
        let addr = addr.clone();
        let reference = reference();
        handles.push(std::thread::spawn(move || {
            let mut worker =
                DeviceWorker::new(Device::new(devices::xavier(), 100 + w as u64), &reference)
                    .with_per_job_seed(BASE_SEED);
            worker.run(&addr)
        }));
    }

    let run = bound.serve(&reference(), n_workers).expect("fleet serve");
    for h in handles {
        let _ = h.join();
    }
    run.store.to_json().to_string()
}

#[test]
fn local_and_fleet_stores_are_byte_identical_at_1_and_3_workers() {
    let local = local_store_json();
    assert!(!local.is_empty() && local.contains("xavier"), "local store looks empty");
    let fleet1 = fleet_store_json(1);
    assert_eq!(
        local, fleet1,
        "1-worker fleet store diverged from the local per-job backend"
    );
    let fleet3 = fleet_store_json(3);
    assert_eq!(
        local, fleet3,
        "3-worker fleet store diverged from the local per-job backend"
    );
}
