//! Smoke tests for the experiment harness: every generator runs at tiny
//! scale and emits non-empty, well-formed output with the expected
//! headline directions (the full-scale numbers live in results/ and
//! EXPERIMENTS.md; these tests keep the generators from rotting).

use thor::exp::{self, ExpConfig};

fn tiny() -> ExpConfig {
    ExpConfig::new(true, 7)
}

#[test]
fn fig2_shows_overestimation() {
    let out = exp::fig2::run(&tiny());
    assert!(out.contains("ratio"));
    // every data row's ratio column is > 1.0
    let ratios: Vec<f64> = out
        .lines()
        .filter(|l| l.starts_with("| ") && !l.contains("ratio"))
        .filter_map(|l| l.split('|').nth(4).and_then(|c| c.trim().parse().ok()))
        .collect();
    assert!(!ratios.is_empty());
    assert!(ratios.iter().all(|&r| r > 1.0), "{ratios:?}");
}

#[test]
fn fig5_series_nonempty() {
    let out = exp::fig5::run(&tiny());
    assert!(out.lines().count() > 5);
    assert!(out.contains("energy J/iter"));
}

#[test]
fn fig6_reports_positive_correlation() {
    let out = exp::fig6::run(&tiny());
    let r: f64 = out
        .lines()
        .find(|l| l.contains("Pearson"))
        .and_then(|l| l.split('=').nth(1))
        .and_then(|s| s.trim().split(' ').next())
        .and_then(|s| s.parse().ok())
        .unwrap();
    assert!(r > 0.5, "time-energy correlation {r}");
}

#[test]
fn a16_spread_shrinks_with_iterations() {
    let out = exp::a16::run(&tiny());
    let cvs: Vec<f64> = out
        .lines()
        .filter(|l| l.starts_with("| ") && l.contains('%'))
        .filter_map(|l| {
            l.split('|')
                .nth(3)
                .and_then(|c| c.trim().trim_end_matches('%').parse::<f64>().ok())
        })
        .collect();
    assert!(cvs.len() >= 4, "{out}");
    assert!(
        cvs.first().unwrap() > cvs.last().unwrap(),
        "spread should shrink: {cvs:?}"
    );
}

#[test]
fn mape_pair_runs_on_every_device() {
    for dev in ["xavier", "tx2"] {
        let (thor_m, flops_m, report) = exp::mape_pair(dev, thor::model::sampler::Family::LeNet5, &tiny());
        assert!(thor_m.is_finite() && flops_m.is_finite());
        assert!(report.total_points() > 0);
    }
}
