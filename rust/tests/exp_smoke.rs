//! Smoke tests for the experiment registry: generators run at tiny scale
//! through the same `Experiment::run` path the CLI/bench/runner use, and
//! their structured reports carry the expected headline directions (the
//! exact quick-mode numbers are pinned by tests/golden_runs.rs; these
//! tests keep the generators from rotting semantically).

use thor::exp::{by_id, ExpConfig, Experiment as _};

fn tiny(id: &str) -> ExpConfig {
    ExpConfig::for_experiment(7, true, id)
}

fn run(id: &str) -> thor::exp::ExpReport {
    by_id(id).expect("registered").run(&tiny(id))
}

#[test]
fn fig2_shows_overestimation() {
    let rep = run("fig2");
    let table = &rep.tables[0];
    let ratios: Vec<f64> = table
        .column("ratio")
        .expect("ratio column")
        .iter()
        .map(|c| c.parse().expect("numeric ratio"))
        .collect();
    assert!(!ratios.is_empty());
    assert!(ratios.iter().all(|&r| r > 1.0), "{ratios:?}");
}

#[test]
fn fig5_series_nonempty() {
    let rep = run("fig5");
    assert_eq!(rep.series.len(), 1);
    let (name, pts) = &rep.series[0].series[0];
    assert_eq!(name, "energy J/iter");
    assert!(pts.len() > 3, "{} points", pts.len());
    assert!(pts.iter().all(|(_, e)| *e > 0.0));
}

#[test]
fn fig6_reports_positive_correlation() {
    let rep = run("fig6");
    let r = rep.get_metric("pearson_r").expect("pearson_r metric");
    assert!(r > 0.5, "time-energy correlation {r}");
}

#[test]
fn a16_spread_shrinks_with_iterations() {
    let rep = run("a16");
    let cvs: Vec<f64> = rep.tables[0]
        .column("spread (CV)")
        .expect("cv column")
        .iter()
        .map(|c| c.trim_end_matches('%').parse().expect("numeric CV"))
        .collect();
    assert!(cvs.len() >= 4, "{cvs:?}");
    assert!(cvs.first().unwrap() > cvs.last().unwrap(), "spread should shrink: {cvs:?}");
}

#[test]
fn fig13_thor_tracks_budget_better_than_flops() {
    let rep = run("fig13");
    // 3 budgets × 2 guidance arms
    assert_eq!(rep.tables[0].rows.len(), 6, "{:?}", rep.tables[0].rows);
    let t50 = rep.get_metric("thor_actual_ratio_50").expect("thor_actual_ratio_50");
    let f50 = rep.get_metric("flops_actual_ratio_50").expect("flops_actual_ratio_50");
    assert!(t50.is_finite() && f50.is_finite());
    // The Fig 13 direction: FLOPs-ratio guidance overshoots the budget
    // by more than THOR's absolute estimates do.
    assert!(t50 < f50, "thor {t50} should beat flops {f50}");
    assert!(t50 < 0.75, "thor landed far over the 50% budget: {t50}");
    let tw = rep.get_metric("thor_within_budget_frac").unwrap();
    let fw = rep.get_metric("flops_within_budget_frac").unwrap();
    assert!(tw >= fw, "thor within-budget {tw} < flops {fw}");
}

#[test]
fn fleet1_fits_all_families_over_loopback() {
    let rep = run("fleet1");
    assert!(rep.error.is_none(), "{:?}", rep.error);
    assert_eq!(rep.get_metric("families_fitted").unwrap(), 5.0);
    assert!(rep.get_metric("jobs_total").unwrap() > 0.0);
    assert_eq!(rep.get_metric("jobs_requeued").unwrap(), 0.0);
    let mape = rep.get_metric("fleet_mape").unwrap();
    assert!(mape.is_finite() && mape >= 0.0, "fleet MAPE {mape}");
    // one row per worker, every worker contributed
    let jobs = rep.tables[0].column("jobs done").expect("jobs column");
    assert_eq!(jobs.len(), 3);
    assert!(jobs.iter().all(|j| j.parse::<usize>().unwrap() > 0), "{jobs:?}");
}

#[test]
fn fleetn_fits_every_device_type_concurrently() {
    let rep = run("fleetN");
    assert!(rep.error.is_none(), "{:?}", rep.error);
    assert_eq!(rep.get_metric("devices").unwrap(), 3.0);
    assert!(rep.get_metric("jobs_total").unwrap() > 0.0);
    for dev in ["xavier", "tx2", "server"] {
        let m = rep.get_metric(&format!("mape_{dev}")).unwrap_or(f64::NAN);
        assert!(m.is_finite() && m >= 0.0, "{dev} MAPE {m}");
        assert!(rep.get_metric(&format!("jobs_{dev}")).unwrap() > 0.0, "{dev} ran no jobs");
    }
    // one table row per device type, per-worker counts present
    assert_eq!(rep.tables[0].rows.len(), 3, "{:?}", rep.tables[0].rows);
    let per_worker = rep.tables[0].column("per-worker jobs").expect("per-worker column");
    assert!(per_worker.iter().all(|c| c.contains('/')), "{per_worker:?}");
}

#[test]
fn fleeth_single_leader_serves_all_three_classes() {
    let rep = run("fleetH");
    assert!(rep.error.is_none(), "{:?}", rep.error);
    assert_eq!(rep.get_metric("devices").unwrap(), 3.0);
    assert_eq!(rep.get_metric("families_fitted").unwrap(), 15.0, "5 families × 3 classes");
    assert!(rep.get_metric("jobs_total").unwrap() > 0.0);
    assert_eq!(rep.get_metric("jobs_requeued").unwrap(), 0.0);
    for dev in ["xavier", "tx2", "server"] {
        let m = rep.get_metric(&format!("mape_{dev}")).unwrap_or(f64::NAN);
        assert!(m.is_finite() && m >= 0.0, "{dev} MAPE {m}");
        assert_eq!(
            rep.get_metric(&format!("families_{dev}")).unwrap(),
            5.0,
            "{dev} is missing families in the shared store"
        );
        assert!(rep.get_metric(&format!("jobs_{dev}")).unwrap() > 0.0, "{dev} ran no jobs");
    }
    // one table row per device class in the single shared report
    assert_eq!(rep.tables[0].rows.len(), 3, "{:?}", rep.tables[0].rows);
}

#[test]
fn fleete_chaos_run_resumes_byte_identically() {
    let rep = run("fleetE");
    assert!(rep.error.is_none(), "{:?}", rep.error);
    assert_eq!(rep.get_metric("leader_a_died").unwrap(), 1.0, "fault injection never fired");
    assert_eq!(
        rep.get_metric("store_byte_equal").unwrap(),
        1.0,
        "resumed store diverged from the uninterrupted run"
    );
    assert_eq!(rep.get_metric("families_fitted").unwrap(), 15.0, "5 families × 3 classes");
    assert!(rep.get_metric("checkpoint_writes").unwrap() >= 6.0);
    // Leader A made real progress before dying, and leader B had real
    // work left: the handover split the run in two non-trivial halves.
    assert!(rep.get_metric("families_checkpointed").unwrap() >= 1.0);
    assert!(rep.get_metric("families_checkpointed").unwrap() < 15.0);
    assert!(rep.get_metric("inflight_resumed").unwrap() >= 1.0, "no in-flight machine resumed");
    assert!(rep.get_metric("jobs_resumed_done").unwrap() > 0.0);
    assert_eq!(
        rep.get_metric("jobs_resumed_done").unwrap(),
        rep.get_metric("jobs_resumed_submitted").unwrap(),
        "resumed leader lost jobs"
    );
    assert_eq!(rep.get_metric("jobs_requeued_resumed").unwrap(), 0.0);
    for dev in ["xavier", "tx2", "server"] {
        let m = rep.get_metric(&format!("mape_{dev}")).unwrap_or(f64::NAN);
        assert!(m.is_finite() && m >= 0.0, "{dev} MAPE {m}");
    }
    assert_eq!(rep.tables[0].rows.len(), 3, "{:?}", rep.tables[0].rows);
}

#[test]
fn serve1_daemon_answers_are_byte_stable() {
    let rep = run("serve1");
    assert!(rep.error.is_none(), "{:?}", rep.error);
    assert!(rep.get_metric("n_queries").unwrap() > 0.0);
    assert_eq!(
        rep.get_metric("byte_stable").unwrap(),
        1.0,
        "daemon answers diverged from local estimate()"
    );
    assert_eq!(rep.get_metric("protocol_errors").unwrap(), 0.0);
    assert!(rep.get_metric("cache_entries").unwrap() > 0.0, "cache never populated");
    assert_eq!(rep.get_metric("clients").unwrap(), 4.0);
}

#[test]
fn gpscale_sparse_arms_stay_close_to_exact() {
    let rep = run("gpscale");
    assert!(rep.error.is_none(), "{:?}", rep.error);
    let table = &rep.tables[0];
    assert_eq!(table.rows.len(), 4, "{:?}", table.rows); // exact + 3 sparse arms
    assert_eq!(table.rows[0][0], "exact");
    let mapes: Vec<f64> = table
        .column("MAPE %")
        .expect("mape column")
        .iter()
        .map(|c| c.parse().expect("numeric MAPE"))
        .collect();
    assert!(mapes.iter().all(|m| m.is_finite() && *m >= 0.0), "{mapes:?}");
    let max_drift: Vec<f64> = table
        .column("max drift vs exact %")
        .expect("drift column")
        .iter()
        .map(|c| c.parse().expect("numeric drift"))
        .collect();
    assert_eq!(max_drift[0], 0.0, "exact arm must have zero drift by construction");
    // The accuracy direction: more inducing points ⇒ the sparse posterior
    // tracks exact more closely.  Tiny-scale bound is loose — the golden
    // pins the exact envelope.
    assert!(
        max_drift[1] >= max_drift[3] || max_drift[3] < 5.0,
        "m=12 should not drift more than m=4 (or must be small): {max_drift:?}"
    );
    assert!(max_drift.iter().all(|d| d.is_finite()), "{max_drift:?}");
}

#[test]
fn mape_pair_runs_on_every_device() {
    for dev in ["xavier", "tx2"] {
        let (thor_m, flops_m, report) =
            thor::exp::mape_pair(dev, thor::model::sampler::Family::LeNet5, &ExpConfig::new(true, 7));
        assert!(thor_m.is_finite() && flops_m.is_finite());
        assert!(report.total_points() > 0);
    }
}

#[test]
fn reports_carry_meta_and_render() {
    let rep = run("fig2");
    assert_eq!(rep.id, "fig2");
    assert!(rep.meta.quick);
    assert_eq!(rep.meta.seed, ExpConfig::derive_seed(7, "fig2"));
    assert_eq!(rep.meta.devices, vec!["xavier".to_string()]);
    let rendered = rep.render();
    assert!(rendered.contains("fig2"));
    assert!(rendered.contains("ratio"));
}
