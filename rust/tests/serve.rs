//! Integration tests for the estimation-serving daemon
//! (`thor serve-estimates` / [`thor::coordinator::estimate_server`]):
//! the serving tier's load-bearing promises, checked over real loopback
//! sockets **under both io models** ([`IoModel::Reactor`], the default,
//! and [`IoModel::Threads`], the legacy thread-per-connection core).
//!
//! 1. **Bit-identity under concurrency** — any number of concurrent
//!    clients, interleaving single and batch requests, receive answers
//!    bit-for-bit equal to a direct local `estimate()` against the same
//!    store.  The shared cache, batch coalescing (including the
//!    reactor's cross-connection micro-batches), and scheduling must
//!    never perturb a single ULP.
//! 2. **Disconnect robustness** — a client dying mid-request (half a
//!    line, garbage framing, or a silent drop) ends only its own
//!    connection: the daemon keeps serving and the shared cache is
//!    neither poisoned nor corrupted (later answers stay bit-identical).
//! 3. **Deadline hardening** ([`thor::coordinator::ServeTuning`]) — a
//!    slow-loris client trickling bytes cannot stall service past the
//!    line deadline (one `est_err`, then the drop), and a connection
//!    idling past the idle timeout is reaped.  Both behaviors are
//!    identical across io models.
//! 4. **Reactor extras** — pipelining (many in-flight correlation ids
//!    on one connection), backpressure on clients that pipeline without
//!    reading replies (`max_inflight` read gating, no reply lost), and
//!    fd-stability across repeated start/shutdown cycles (the reactor's
//!    stop-flag + wake-pipe shutdown makes no connections and leaks no
//!    fds).

use std::time::Duration;

use thor::coordinator::{
    slow_loris_send, EstimateClient, EstimateServer, EstimateServerHandle, IoModel, Msg,
    ServeTuning,
};
use thor::model::spec::parse_spec;
use thor::model::zoo;
use thor::simdevice::{devices, Device};
use thor::thor::estimator::estimate;
use thor::thor::store::GpStore;
use thor::thor::{Thor, ThorConfig};
use thor::util::json::Json;

const BOTH_MODELS: [IoModel; 2] = [IoModel::Reactor, IoModel::Threads];

/// Deterministic fitted store covering the cnn5 families on one device.
fn profiled_store(device: &str, seed: u64) -> GpStore {
    let profile = devices::by_name(device).expect("device");
    let mut dev = Device::new(profile, seed);
    let mut thor = Thor::new(ThorConfig::quick());
    thor.profile_local(&mut dev, &zoo::cnn5(&[32, 64, 128, 256], 16, 10));
    thor.store
}

/// Rebuild an identical store from its JSON artifact (profiling is the
/// expensive step; each io-model pass gets its own copy of one fit).
fn reload(json: &str) -> GpStore {
    GpStore::from_json(&Json::parse(json).unwrap()).expect("reload store")
}

const SPECS: [&str; 4] =
    ["cnn5:8,16,32,64:16", "cnn5:4,8,16,32:16", "cnn5:16,32,64,128:16", "cnn5:24,48,96,20:16"];

/// (energy, variance) bit patterns a local estimate() produces per spec.
fn expected_bits(store: &GpStore, device: &str) -> Vec<(u64, u64)> {
    SPECS
        .iter()
        .map(|s| {
            let e = estimate(store, device, &parse_spec(s).unwrap()).unwrap();
            (e.energy_per_iter.to_bits(), e.variance.to_bits())
        })
        .collect()
}

fn start_daemon(store: GpStore, threads: usize, io: IoModel) -> EstimateServerHandle {
    EstimateServer::bind("127.0.0.1:0", store).unwrap().with_io_model(io).start(threads).unwrap()
}

fn start_tuned(
    store: GpStore,
    threads: usize,
    io: IoModel,
    tuning: ServeTuning,
) -> EstimateServerHandle {
    EstimateServer::bind("127.0.0.1:0", store)
        .unwrap()
        .with_io_model(io)
        .with_tuning(tuning)
        .start(threads)
        .unwrap()
}

#[test]
fn six_concurrent_clients_get_bit_identical_answers_under_both_io_models() {
    const CLIENTS: usize = 6;
    const ROUNDS: usize = 10;
    let store = profiled_store("xavier", 21);
    let expected = expected_bits(&store, "xavier");
    let json = store.to_json().to_string();
    for io in BOTH_MODELS {
        let handle = start_daemon(reload(&json), CLIENTS, io);
        let addr = handle.addr();

        let mut joins = Vec::new();
        for c in 0..CLIENTS {
            let expected = expected.clone();
            joins.push(std::thread::spawn(move || {
                let mut client = EstimateClient::connect(&addr).expect("connect");
                let batch: Vec<(String, String)> =
                    SPECS.iter().map(|s| ("xavier".to_string(), s.to_string())).collect();
                for r in 0..ROUNDS {
                    // Start each client at a different spec so the cache
                    // sees genuinely interleaved access patterns.
                    for i in 0..SPECS.len() {
                        let si = (c + r + i) % SPECS.len();
                        let (e, v) = client.estimate("xavier", SPECS[si]).expect("estimate");
                        assert_eq!(
                            (e.to_bits(), v.to_bits()),
                            expected[si],
                            "[{io:?}] client {c} round {r} spec {si}: daemon answer diverged"
                        );
                    }
                    let got = client.estimate_batch(&batch).expect("batch");
                    for (si, g) in got.iter().enumerate() {
                        let (e, v) = g.as_ref().expect("batch entry");
                        assert_eq!(
                            (e.to_bits(), v.to_bits()),
                            expected[si],
                            "[{io:?}] batch spec {si}"
                        );
                    }
                }
            }));
        }
        for j in joins {
            j.join().expect("client thread");
        }
        let stats = handle.shutdown();
        // >= not ==: a shutdown-unblocking dummy connect can in principle
        // be counted if a thread-model accept races the stop-flag store.
        assert!(
            stats.connections >= CLIENTS as u64,
            "[{io:?}] {} connections",
            stats.connections
        );
        assert_eq!(stats.requests, (CLIENTS * ROUNDS * (SPECS.len() + 1)) as u64, "[{io:?}]");
        assert_eq!(stats.errors, 0, "[{io:?}]");
    }
}

#[test]
fn killed_mid_request_clients_cannot_wedge_the_daemon_or_poison_the_cache() {
    let store = profiled_store("xavier", 22);
    let expected = expected_bits(&store, "xavier");
    let json = store.to_json().to_string();
    for io in BOTH_MODELS {
        let handle = start_daemon(reload(&json), 3, io);
        let addr = handle.addr();

        // Warm the cache through a well-behaved client first.
        let mut good = EstimateClient::connect(&addr).unwrap();
        let (e, v) = good.estimate("xavier", SPECS[0]).unwrap();
        assert_eq!((e.to_bits(), v.to_bits()), expected[0], "[{io:?}]");

        // Abuse the daemon in every way a dying client can.
        {
            // Half a request line, then a silent drop (no newline ever comes).
            let mut half = EstimateClient::connect(&addr).unwrap();
            half.send_raw(b"{\"type\":\"est\",\"id\":1,\"dev").unwrap();
            drop(half);
        }
        {
            // Garbage framing: one error reply, then the server hangs up.
            let mut garbage = EstimateClient::connect(&addr).unwrap();
            garbage.send_raw(b"%%% not json at all %%%\n").unwrap();
            match garbage.read_reply().unwrap() {
                Msg::EstimateError { id: 0, .. } => {}
                other => panic!("[{io:?}] expected a framing error reply, got {other:?}"),
            }
            assert!(
                garbage.read_reply().is_err(),
                "[{io:?}] connection must close after framing break"
            );
        }
        {
            // A valid request whose reply the client never reads.
            let mut rude = EstimateClient::connect(&addr).unwrap();
            rude.send_raw(
                b"{\"type\":\"est\",\"id\":7,\"device\":\"xavier\",\"model\":\"cnn5:8,16,32,64:16\"}\n",
            )
            .unwrap();
            drop(rude);
        }

        // The daemon must still serve — the original connection and fresh
        // ones — with answers still bit-identical to the pre-abuse truth.
        for (si, want) in expected.iter().enumerate() {
            let (e, v) = good.estimate("xavier", SPECS[si]).unwrap();
            assert_eq!((e.to_bits(), v.to_bits()), *want, "[{io:?}] surviving conn, spec {si}");
        }
        drop(good);
        for (si, want) in expected.iter().enumerate() {
            let mut fresh = EstimateClient::connect(&addr).unwrap();
            let (e, v) = fresh.estimate("xavier", SPECS[si]).unwrap();
            assert_eq!((e.to_bits(), v.to_bits()), *want, "[{io:?}] fresh conn, spec {si}");
        }
        let stats = handle.shutdown();
        assert!(stats.errors >= 1, "[{io:?}] the garbage line must have been counted");
        assert!(!handle_is_wedged(stats.requests), "[{io:?}] daemon stopped serving requests");
    }
}

/// Trivial readability helper: by the time shutdown returns we must have
/// served the warm-up, the rude request, and the 2×4 post-abuse sweeps.
fn handle_is_wedged(requests_served: u64) -> bool {
    requests_served < (1 + 1 + 2 * SPECS.len()) as u64
}

#[test]
fn swap_store_under_concurrent_load_never_serves_torn_answers() {
    // Hot reload while six clients hammer the daemon: every reply must
    // come entirely from one store generation — the old or the new —
    // never a mix.  Single answers must match one generation bit-for-bit
    // and a coalesced batch must be all-old or all-new; the reactor's
    // one-snapshot-per-micro-batch rule makes this hold even when
    // queries from several connections share a GP solve.
    const CLIENTS: usize = 6;
    const ROUNDS: usize = 30;
    const SWAPS: usize = 40;
    let store_a = profiled_store("xavier", 31);
    let store_b = profiled_store("xavier", 32);
    let bits_a = expected_bits(&store_a, "xavier");
    let bits_b = expected_bits(&store_b, "xavier");
    assert_ne!(bits_a, bits_b, "profiling seeds must produce different fits");
    // Each swap installs a fresh deserialization of the same fitted
    // artifact: predictions are bit-identical across reloads (the GP
    // JSON-roundtrip pin), but every reload carries a new cache
    // generation — exactly the operator's `thor serve-estimates` reload
    // path.
    let json_a = store_a.to_json().to_string();
    let json_b = store_b.to_json().to_string();

    for io in BOTH_MODELS {
        let handle = start_daemon(reload(&json_a), CLIENTS, io);
        let addr = handle.addr();

        std::thread::scope(|scope| {
            for c in 0..CLIENTS {
                let (bits_a, bits_b) = (&bits_a, &bits_b);
                scope.spawn(move || {
                    let mut client = EstimateClient::connect(&addr).expect("connect");
                    let batch: Vec<(String, String)> =
                        SPECS.iter().map(|s| ("xavier".to_string(), s.to_string())).collect();
                    for r in 0..ROUNDS {
                        for i in 0..SPECS.len() {
                            let si = (c + r + i) % SPECS.len();
                            let (e, v) = client.estimate("xavier", SPECS[si]).expect("estimate");
                            let got = (e.to_bits(), v.to_bits());
                            assert!(
                                got == bits_a[si] || got == bits_b[si],
                                "[{io:?}] client {c} round {r} spec {si}: answer from neither \
                                 generation"
                            );
                        }
                        let got = client.estimate_batch(&batch).expect("batch");
                        let bits: Vec<(u64, u64)> = got
                            .iter()
                            .map(|g| {
                                let (e, v) = g.as_ref().expect("batch entry");
                                (e.to_bits(), v.to_bits())
                            })
                            .collect();
                        assert!(
                            bits == *bits_a || bits == *bits_b,
                            "[{io:?}] client {c} round {r}: torn batch mixes store \
                             generations: {bits:?}"
                        );
                    }
                });
            }
            // The swapper, racing the clients: alternate B/A reloads.
            for s in 0..SWAPS {
                handle.swap_store(reload(if s % 2 == 0 { &json_b } else { &json_a }));
                std::thread::yield_now();
            }
        });
        let stats = handle.shutdown();
        assert_eq!(stats.errors, 0, "[{io:?}] swapping under load surfaced request errors");
        assert_eq!(stats.requests, (CLIENTS * ROUNDS * (SPECS.len() + 1)) as u64, "[{io:?}]");
    }
}

#[test]
fn shutdown_message_is_a_polite_close_not_an_error() {
    let store = profiled_store("xavier", 23);
    let json = store.to_json().to_string();
    for io in BOTH_MODELS {
        let handle = start_daemon(reload(&json), 2, io);
        let mut client = EstimateClient::connect(&handle.addr()).unwrap();
        client.send_raw(Msg::Shutdown.encode().as_bytes()).unwrap();
        assert!(client.read_reply().is_err(), "[{io:?}] server should close after Shutdown");
        drop(client);
        let stats = handle.shutdown();
        assert_eq!(stats.errors, 0, "[{io:?}]");
    }
}

#[test]
fn slow_loris_client_cannot_stall_service_past_the_line_deadline() {
    let store = profiled_store("xavier", 24);
    let expected = expected_bits(&store, "xavier");
    let json = store.to_json().to_string();
    let tuning = ServeTuning {
        line_timeout: Duration::from_millis(200),
        poll: Duration::from_millis(25),
        ..ServeTuning::default()
    };
    for io in BOTH_MODELS {
        // ONE serving thread: under the thread model, if the loris held
        // it past the deadline the healthy client below could never be
        // served; under the reactor the event loop must cut the loris at
        // the deadline while serving others throughout.
        let handle = start_tuned(reload(&json), 1, io, tuning);
        let addr = handle.addr();

        // A valid request trickled at 50ms/byte — it cannot complete its
        // line within the 200ms deadline, so the server must cut it off.
        const REQ: &[u8] =
            b"{\"type\":\"est\",\"id\":1,\"device\":\"xavier\",\"model\":\"cnn5:8,16,32,64:16\"}\n";
        let loris = std::thread::spawn(move || {
            let mut stream = std::net::TcpStream::connect(addr).expect("loris connect");
            slow_loris_send(&mut stream, REQ, Duration::from_millis(50))
        });
        // Let the loris win the single worker's accept first.
        std::thread::sleep(Duration::from_millis(50));

        // The healthy client gets served if and only if the loris cannot
        // monopolize the serving core.
        let mut client = EstimateClient::connect(&addr).expect("healthy connect");
        let (e, v) = client.estimate("xavier", SPECS[0]).expect("healthy estimate");
        assert_eq!((e.to_bits(), v.to_bits()), expected[0], "[{io:?}]");

        let sent = loris.join().expect("loris thread");
        assert!(sent < REQ.len(), "[{io:?}] loris was never cut off (sent all {sent} bytes)");
        drop(client);
        let stats = handle.shutdown();
        assert!(
            stats.errors >= 1,
            "[{io:?}] the stalled line must be answered with one est_err: {stats:?}"
        );
    }
}

#[test]
fn idle_connections_are_reaped_and_the_daemon_keeps_serving() {
    let store = profiled_store("xavier", 25);
    let expected = expected_bits(&store, "xavier");
    let json = store.to_json().to_string();
    let tuning = ServeTuning {
        idle_timeout: Duration::from_millis(150),
        poll: Duration::from_millis(25),
        ..ServeTuning::default()
    };
    for io in BOTH_MODELS {
        let handle = start_tuned(reload(&json), 2, io, tuning);

        // One served request, then silence past the idle timeout.
        let mut client = EstimateClient::connect(&handle.addr()).unwrap();
        let (e, v) = client.estimate("xavier", SPECS[0]).unwrap();
        assert_eq!((e.to_bits(), v.to_bits()), expected[0], "[{io:?}]");
        std::thread::sleep(Duration::from_millis(400));
        assert!(
            client.estimate("xavier", SPECS[0]).is_err(),
            "[{io:?}] idle connection should have been reaped"
        );
        // The reap freed serving capacity: fresh connections serve
        // bit-identical answers.
        let mut fresh = EstimateClient::connect(&handle.addr()).unwrap();
        let (e, v) = fresh.estimate("xavier", SPECS[1]).unwrap();
        assert_eq!((e.to_bits(), v.to_bits()), expected[1], "[{io:?}]");
        drop(fresh);
        drop(client);
        let stats = handle.shutdown();
        assert!(stats.reaped >= 1, "[{io:?}] idle reap never fired: {stats:?}");
        assert_eq!(stats.errors, 0, "[{io:?}] an idle reap is silent, not an error");
    }
}

#[test]
fn pipelined_client_matches_64_in_flight_replies_by_correlation_id() {
    // One connection, 64 requests fired before any reply is read.  The
    // reactor may answer out of send order (micro-batches complete on
    // any compute worker); the contract is that every reply carries its
    // request's correlation id and the right bits for *that* id's spec.
    let store = profiled_store("xavier", 26);
    let expected = expected_bits(&store, "xavier");
    let json = store.to_json().to_string();
    const IN_FLIGHT: usize = 64;
    for io in BOTH_MODELS {
        let handle = start_daemon(reload(&json), 2, io);
        let mut client = EstimateClient::connect(&handle.addr()).unwrap();
        let mut id_spec = std::collections::HashMap::new();
        for i in 0..IN_FLIGHT {
            let si = i % SPECS.len();
            let id = client.submit("xavier", SPECS[si]).expect("submit");
            assert!(id_spec.insert(id, si).is_none(), "correlation ids must be unique");
        }
        for _ in 0..IN_FLIGHT {
            let (id, outcome) = client.recv_single().expect("recv");
            let si = *id_spec.get(&id).expect("reply id matches a submitted request");
            let (e, v) = outcome.expect("pipelined estimate");
            assert_eq!(
                (e.to_bits(), v.to_bits()),
                expected[si],
                "[{io:?}] pipelined reply id {id} (spec {si}) diverged"
            );
            id_spec.remove(&id);
        }
        assert!(id_spec.is_empty(), "[{io:?}] every submitted id must be answered exactly once");
        drop(client);
        let stats = handle.shutdown();
        assert_eq!(stats.requests, IN_FLIGHT as u64, "[{io:?}]");
        assert_eq!(stats.errors, 0, "[{io:?}]");
    }
}

#[test]
fn unread_reply_backpressure_gates_the_rude_client_without_starving_the_polite_one() {
    // Reactor-specific: a client that pipelines heavily while never
    // reading replies gets read-gated (max_inflight + write_highwater)
    // instead of ballooning server memory or wedging the loop.  A
    // polite client on the same daemon stays served throughout, and
    // when the rude client finally drains, every reply is present,
    // correct, and matched by correlation id.  (The backlog is sized to
    // fit default kernel socket buffers: the rude client's blocking
    // submit loop must never deadlock against its own unread replies.)
    const RUDE_REQUESTS: usize = 512;
    let store = profiled_store("xavier", 27);
    let expected = expected_bits(&store, "xavier");
    let tuning = ServeTuning {
        max_inflight: 8,
        write_highwater: 4096,
        poll: Duration::from_millis(25),
        ..ServeTuning::default()
    };
    let handle = start_tuned(store, 2, IoModel::Reactor, tuning);
    let addr = handle.addr();

    let mut rude = EstimateClient::connect(&addr).unwrap();
    let mut id_spec = std::collections::HashMap::new();
    for i in 0..RUDE_REQUESTS {
        let si = i % SPECS.len();
        let id = rude.submit("xavier", SPECS[si]).expect("rude submit");
        id_spec.insert(id, si);
    }

    // While the rude backlog is pending, a polite client must be served
    // promptly and bit-identically.
    let mut polite = EstimateClient::connect(&addr).unwrap();
    for r in 0..20 {
        let si = r % SPECS.len();
        let (e, v) = polite.estimate("xavier", SPECS[si]).expect("polite estimate");
        assert_eq!((e.to_bits(), v.to_bits()), expected[si], "polite round {r}");
    }
    drop(polite);

    // Now drain: all RUDE_REQUESTS replies arrive, each correct for the
    // id it carries.
    for _ in 0..RUDE_REQUESTS {
        let (id, outcome) = rude.recv_single().expect("rude recv");
        let si = id_spec.remove(&id).expect("reply id matches a submitted request");
        let (e, v) = outcome.expect("rude estimate");
        assert_eq!((e.to_bits(), v.to_bits()), expected[si], "rude reply id {id}");
    }
    assert!(id_spec.is_empty(), "every rude request must be answered exactly once");
    drop(rude);
    let stats = handle.shutdown();
    assert_eq!(stats.requests, (RUDE_REQUESTS + 20) as u64);
    assert_eq!(stats.errors, 0);
}

/// The reactor shutdown fix: stop-flag + wake pipe, no dummy connects.
/// 100 start/shutdown cycles must hold the process fd count flat —
/// every cycle's listener, epoll fd, pipe pair, and any accepted
/// connection are all closed on shutdown.
#[cfg(target_os = "linux")]
#[test]
fn reactor_shutdown_does_not_leak_fds_across_100_cycles() {
    fn open_fds() -> usize {
        std::fs::read_dir("/proc/self/fd").expect("procfs").count()
    }
    let store = profiled_store("xavier", 28);
    let expected = expected_bits(&store, "xavier");
    let json = store.to_json().to_string();
    let before = open_fds();
    for cycle in 0..100 {
        let handle = start_daemon(reload(&json), 1, IoModel::Reactor);
        // Exercise accept + serve on a sample of cycles so the fd
        // accounting covers live connections, not just idle daemons.
        if cycle % 10 == 0 {
            let mut client = EstimateClient::connect(&handle.addr()).unwrap();
            let (e, v) = client.estimate("xavier", SPECS[0]).unwrap();
            assert_eq!((e.to_bits(), v.to_bits()), expected[0], "cycle {cycle}");
        }
        handle.shutdown();
    }
    let after = open_fds();
    assert!(
        after <= before + 8,
        "fd count grew across 100 reactor cycles: {before} -> {after}"
    );
}
