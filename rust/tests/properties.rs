//! Cross-module property tests: invariants that tie the layer parser,
//! the workload compiler, the device simulator, the GP library and the
//! estimator together over randomized inputs (seeded in-repo harness —
//! `util::proptest`; the proptest crate is unavailable offline).

use thor::exp::{by_id, ExpConfig, ExpReport, Experiment, Runner, Subtask, SubtaskOutput};
use thor::model::sampler::{sample, Family};
use thor::model::{zoo, LayerKind};
use thor::prop_assert;
use thor::simdevice::{devices, exec::ideal_energy_per_iter, Device};
use thor::thor::parse::{parse, Position};
use thor::thor::profiler;
use thor::thor::{estimator, Thor, ThorConfig};
use thor::util::json::Json;
use thor::util::proptest::{check, Config};
use thor::util::rng::Pcg64;
use thor::workload::{fusion::fuse, lower::lower, Phase};

fn random_family(r: &mut Pcg64) -> Family {
    *r.choose(&[
        Family::LeNet5,
        Family::Cnn5,
        Family::Har,
        Family::Lstm,
        Family::Transformer,
        Family::ResNet20,
    ])
}

#[test]
fn prop_parse_positions_well_formed() {
    // Exactly one input and one output group; hidden strictly between.
    check(
        "parse positions",
        Config { cases: 60, seed: 101 },
        |r| sample(random_family(r), r, 10),
        |g| {
            let p = parse(g);
            let inputs = p.groups.iter().filter(|x| x.key.position == Position::Input).count();
            let outputs = p.groups.iter().filter(|x| x.key.position == Position::Output).count();
            prop_assert!(inputs == 1, "{} inputs", inputs);
            prop_assert!(outputs == 1, "{} outputs", outputs);
            prop_assert!(p.groups[0].key.position == Position::Input, "first not input");
            prop_assert!(p.groups.last().unwrap().key.position == Position::Output, "last not output");
            // family assignment is a partition
            prop_assert!(p.assignment.len() == p.groups.len(), "assignment arity");
            prop_assert!(p.assignment.iter().all(|&i| i < p.families.len()), "dangling family");
            Ok(())
        },
    );
}

#[test]
fn prop_parse_groups_cover_all_parametric_layers() {
    check(
        "groups cover parametric layers",
        Config { cases: 40, seed: 103 },
        |r| sample(random_family(r), r, 10),
        |g| {
            let p = parse(g);
            let parametric = g.layers.iter().filter(|l| l.kind.is_parametric()).count();
            prop_assert!(p.groups.len() == parametric, "{} groups vs {} parametric", p.groups.len(), parametric);
            // grouped tails are all non-parametric
            for grp in &p.groups {
                prop_assert!(
                    grp.tail.iter().all(|t| !t.kind.is_parametric()),
                    "parametric layer in a tail"
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fusion_conserves_flops_and_reduces_launches() {
    check(
        "fusion conservation",
        Config { cases: 40, seed: 107 },
        |r| sample(random_family(r), r, 10),
        |g| {
            let t = lower(g);
            let f = fuse(&t);
            let rel = (f.total_flops() - t.total_flops()).abs() / t.total_flops();
            prop_assert!(rel < 1e-9, "flops changed by {rel}");
            prop_assert!(f.launches() <= t.launches(), "fusion added launches");
            prop_assert!(f.total_bytes() <= t.total_bytes() + 1.0, "fusion added bytes");
            Ok(())
        },
    );
}

#[test]
fn prop_trace_phases_ordered() {
    // All forward ops precede all backward ops precede the update.
    check(
        "phase ordering",
        Config { cases: 30, seed: 109 },
        |r| sample(random_family(r), r, 10),
        |g| {
            let t = lower(g);
            let phase_rank = |p: Phase| match p {
                Phase::Forward => 0,
                Phase::Backward => 1,
                Phase::Update => 2,
            };
            let ranks: Vec<u8> = t.ops.iter().map(|o| phase_rank(o.phase)).collect();
            prop_assert!(ranks.windows(2).all(|w| w[0] <= w[1]), "phases interleaved");
            Ok(())
        },
    );
}

#[test]
fn prop_energy_monotone_in_iterations() {
    check(
        "energy grows with iterations",
        Config { cases: 12, seed: 113 },
        |r| {
            let g = sample(Family::Cnn5, r, 10);
            (g, r.next_u64())
        },
        |(g, seed)| {
            let tr = fuse(&lower(g));
            let mut d1 = Device::new(devices::tx2(), *seed);
            let mut d2 = Device::new(devices::tx2(), *seed);
            let e50 = d1.run(&tr, 50).energy_j;
            let e200 = d2.run(&tr, 200).energy_j;
            prop_assert!(e200 > 1.5 * e50, "e200 {e200} vs e50 {e50}");
            Ok(())
        },
    );
}

#[test]
fn prop_ideal_energy_additive_over_trace_partition() {
    // Splitting a trace at any point conserves the (state-free) ideal
    // energy — the simulator-side face of layer-wise additivity.
    check(
        "ideal energy additive",
        Config { cases: 24, seed: 127 },
        |r| {
            let g = sample(Family::Cnn5, r, 10);
            let tr = fuse(&lower(&g));
            let cut = r.range_usize(1, tr.ops.len().saturating_sub(1).max(1));
            (tr, cut)
        },
        |(tr, cut)| {
            let p = devices::xavier();
            let whole = ideal_energy_per_iter(&p, tr);
            let a = thor::workload::Trace { ops: tr.ops[..*cut].to_vec() };
            let b = thor::workload::Trace { ops: tr.ops[*cut..].to_vec() };
            let parts = ideal_energy_per_iter(&p, &a) + ideal_energy_per_iter(&p, &b);
            prop_assert!(((whole - parts) / whole).abs() < 1e-9, "{whole} vs {parts}");
            Ok(())
        },
    );
}

#[test]
fn prop_variant_graphs_simulate_positively_on_all_devices() {
    check(
        "variants measurable everywhere",
        Config { cases: 20, seed: 131 },
        |r| {
            let fam = *r.choose(&[Family::Cnn5, Family::LeNet5, Family::Har]);
            let reference = match fam {
                Family::Cnn5 => zoo::cnn5(&[32, 64, 128, 256], 28, 10),
                Family::LeNet5 => zoo::lenet5(&[6, 16, 120, 84], 10),
                _ => zoo::har(&[32, 64, 128], 10),
            };
            (reference, r.range_usize(1, 64), r.range_usize(1, 64), r.next_u64() % 5)
        },
        |(reference, a, b, dev_idx)| {
            let parsed = parse(reference);
            let inp = parsed.input_groups().next().unwrap();
            let out = parsed.output_groups().next().unwrap();
            let hid = parsed.hidden_groups().next().unwrap();
            let (g, _, _) = profiler::hidden_variant(inp, hid, out, *a, *b);
            let profile = devices::all()[*dev_idx as usize].clone();
            let mut dev = Device::new(profile, 1);
            let (e, t) = profiler::measure(&mut dev, &g, 30);
            prop_assert!(e > 0.0 && t > 0.0, "e={e} t={t}");
            Ok(())
        },
    );
}

#[test]
fn prop_layerwise_estimates_sum_to_pipeline_estimate() {
    // Layer-wise energy additivity (paper eq. 4): the per-layer estimates
    // reported by `thor::estimator` must sum to the whole-model estimate
    // returned by the `thor::pipeline` path, across sampled architectures
    // and every simulated device in the fleet.
    let reference = zoo::cnn5(&[32, 64, 128, 256], 28, 10);
    let fleet: Vec<(String, Thor)> = devices::all()
        .into_iter()
        .map(|p| {
            let name = p.name.to_string();
            let mut dev = Device::new(p, 11);
            let mut t = Thor::new(ThorConfig::quick());
            t.profile_local(&mut dev, &reference);
            (name, t)
        })
        .collect();
    check(
        "estimator additivity",
        Config { cases: 20, seed: 163 },
        |r| (sample(Family::Cnn5, r, 10), r.range_usize(0, fleet.len() - 1)),
        |(g, di)| {
            let (dev_name, thor) = &fleet[*di];
            let whole = thor.estimate(dev_name, g).map_err(|e| e.to_string())?;
            let direct = estimator::estimate(&thor.store, dev_name, g).map_err(|e| e.to_string())?;
            let sum: f64 = whole.per_layer.iter().map(|(_, _, e)| e).sum();
            let tol = 1e-9 * whole.energy_per_iter.abs().max(1e-12);
            prop_assert!(
                (sum - whole.energy_per_iter).abs() <= tol,
                "per-layer sum {sum} vs whole-model {} on {dev_name}",
                whole.energy_per_iter
            );
            prop_assert!(
                (direct.energy_per_iter - whole.energy_per_iter).abs() <= tol,
                "estimator {} vs pipeline {} on {dev_name}",
                direct.energy_per_iter,
                whole.energy_per_iter
            );
            prop_assert!(
                whole.per_layer.len() == parse(g).groups.len(),
                "{} per-layer terms for {} groups",
                whole.per_layer.len(),
                parse(g).groups.len()
            );
            Ok(())
        },
    );
}

#[test]
fn prop_json_fuzz_never_panics() {
    // Random byte soup must either parse or return Err — never panic.
    check(
        "json fuzz",
        Config { cases: 500, seed: 137 },
        |r| {
            let n = r.range_usize(0, 64);
            let charset: Vec<char> = r#"{}[]",:0123456789.eE+-truefalsnl \n"#.chars().collect();
            (0..n).map(|_| *r.choose(&charset)).collect::<String>()
        },
        |s| {
            let _ = Json::parse(s); // Result either way; a panic fails the test
            Ok(())
        },
    );
}

#[test]
fn prop_estimator_sum_invariance_under_width_scaling() {
    // Estimates from a synthetic linear store scale monotonically with
    // uniform width scaling of the model.
    check(
        "estimate monotone in width",
        Config { cases: 16, seed: 139 },
        |r| (r.range_usize(2, 8), r.range_usize(9, 16)),
        |&(w_small, w_big)| {
            let small = zoo::cnn5(&[w_small, 2 * w_small, 4 * w_small, 8 * w_small], 16, 10);
            let big = zoo::cnn5(&[w_big, 2 * w_big, 4 * w_big, 8 * w_big], 16, 10);
            let p = devices::xavier();
            let e_s = ideal_energy_per_iter(&p, &fuse(&lower(&small)));
            let e_b = ideal_energy_per_iter(&p, &fuse(&lower(&big)));
            prop_assert!(e_b > e_s, "{e_b} vs {e_s}");
            Ok(())
        },
    );
}

#[test]
fn prop_devices_produce_distinct_energy_profiles() {
    // Heterogeneity: the same model must cost measurably different
    // energy across device types (the reason per-device GPs exist).
    check(
        "device heterogeneity",
        Config { cases: 10, seed: 149 },
        |r| sample(Family::Cnn5, r, 10),
        |g| {
            let tr = fuse(&lower(g));
            let energies: Vec<f64> = devices::all()
                .into_iter()
                .map(|p| ideal_energy_per_iter(&p, &tr))
                .collect();
            // Pairs of devices may legitimately cross for a particular
            // model; heterogeneity means the fleet-wide spread is large.
            let max = energies.iter().cloned().fold(0.0f64, f64::max);
            let min = energies.iter().cloned().fold(f64::INFINITY, f64::min);
            prop_assert!(max / min > 1.3, "fleet energy spread too small: {energies:?}");
            Ok(())
        },
    );
}

#[test]
fn prop_elastic_jobqueue_exactly_once_under_join_death_rejoin() {
    // The elasticity contract of the leader's scheduler: under arbitrary
    // randomized schedules of submit / assign / complete / worker-death
    // / same-class rejoin (fresh, strictly increasing ids — exactly how
    // the accept loop files reconnections), every job completes exactly
    // once, never on a foreign class, and the requeue ledger counts
    // exactly the injected deaths-with-a-job-in-flight.
    use std::collections::BTreeMap;
    use thor::coordinator::JobQueue;
    const CLASSES: [&str; 3] = ["xavier", "tx2", "server"];
    check(
        "elastic jobqueue",
        Config { cases: 64, seed: 167 },
        |r| {
            (0..r.range_usize(20, 80))
                .map(|_| (r.range_usize(0, 4) as u8, r.next_u64()))
                .collect::<Vec<_>>()
        },
        |ops| {
            let mut q = JobQueue::new();
            // id → class, dead or alive (the leader's Hello ledger);
            // ids are never reused across incarnations.
            let mut class_of: Vec<&str> = CLASSES.to_vec();
            let mut live: Vec<usize> = (0..CLASSES.len()).collect();
            let mut held: BTreeMap<usize, u64> = BTreeMap::new();
            let mut completions: BTreeMap<u64, &str> = BTreeMap::new();
            let mut submitted = 0usize;
            let (mut deaths, mut deaths_with_job, mut rejoins, mut requeued_total) =
                (0usize, 0usize, 0usize, 0usize);
            for (op, salt) in ops {
                let salt = *salt as usize;
                match op {
                    0 => {
                        q.submit(CLASSES[salt % CLASSES.len()], "f", vec![salt % 7], 10);
                        submitted += 1;
                    }
                    1 | 2 => {
                        let w = live[salt % live.len()];
                        if let Some(j) = q.assign(w, class_of[w]) {
                            prop_assert!(
                                j.device == class_of[w],
                                "{} job assigned to a {} worker",
                                j.device,
                                class_of[w]
                            );
                            prop_assert!(held.insert(w, j.id).is_none(), "double assignment");
                        }
                    }
                    3 => {
                        if held.is_empty() {
                            continue;
                        }
                        let w = *held.keys().nth(salt % held.len()).unwrap();
                        let id = held.remove(&w).unwrap();
                        prop_assert!(q.complete(id, w), "live completion rejected");
                        prop_assert!(
                            completions.insert(id, class_of[w]).is_none(),
                            "job {id} completed twice"
                        );
                    }
                    _ => {
                        // Kill a random live worker, then rejoin its
                        // class as a fresh id (the dead id stays retired).
                        let w = live.swap_remove(salt % live.len());
                        deaths += 1;
                        let held_job = held.remove(&w);
                        if held_job.is_some() {
                            deaths_with_job += 1;
                        }
                        requeued_total += q.requeue_worker(w);
                        if let Some(id) = held_job {
                            prop_assert!(
                                !q.complete(id, w),
                                "stale result from dead incarnation accepted"
                            );
                        }
                        class_of.push(class_of[w]);
                        live.push(class_of.len() - 1);
                        rejoins += 1;
                    }
                }
            }
            // At-most-one-outstanding means each death requeues exactly
            // its held job (0 or 1): the ledger equals the fault count.
            prop_assert!(
                requeued_total == deaths_with_job,
                "{requeued_total} requeued vs {deaths_with_job} deaths with a job in flight"
            );
            prop_assert!(rejoins == deaths, "every death rejoined");
            // Drain with the surviving fleet — every class always has a
            // live worker because kills pair with same-class rejoins.
            for (w, id) in std::mem::take(&mut held) {
                prop_assert!(q.complete(id, w), "drain completion rejected");
                prop_assert!(completions.insert(id, class_of[w]).is_none(), "completed twice");
            }
            let mut guard = 0;
            while q.pending() > 0 {
                guard += 1;
                prop_assert!(guard < 100_000, "drain did not terminate");
                for &w in &live {
                    if let Some(j) = q.assign(w, class_of[w]) {
                        prop_assert!(j.device == class_of[w], "cross-class drain assignment");
                        prop_assert!(q.complete(j.id, w), "drain completion rejected");
                        prop_assert!(
                            completions.insert(j.id, class_of[w]).is_none(),
                            "completed twice"
                        );
                    }
                }
            }
            prop_assert!(
                completions.len() == submitted,
                "{} completions for {submitted} submitted jobs",
                completions.len()
            );
            prop_assert!(q.done() == submitted, "queue ledger disagrees");
            prop_assert!(
                CLASSES.iter().map(|c| q.done_for(c)).sum::<usize>() == q.done(),
                "per-class ledgers do not add up"
            );
            // Exactly-once *per class*: every completion happened on a
            // worker of the job's own class.
            for (id, class) in &completions {
                prop_assert!(
                    q.get(*id).map(|j| j.device.as_str()) == Some(*class),
                    "job {id} completed on foreign class {class}"
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_speculative_jobqueue_exactly_once_under_randomized_stalls() {
    // The straggler contract of the leader's scheduler: under arbitrary
    // randomized schedules of submit / assign / speculative re-issue /
    // first-result-wins completion / stall-kill (of either the primary
    // or the speculative runner), every job completes exactly once, the
    // rival runner's duplicate result is always rejected, a worker never
    // holds two jobs (primary or speculative), and the requeue ledger
    // counts exactly the stalls that had no speculative runner to
    // promote.
    use std::collections::BTreeMap;
    use thor::coordinator::JobQueue;
    const CLASSES: [&str; 2] = ["xavier", "tx2"];
    // Three workers per class, so re-speculation (replacing a stalled
    // speculative runner with the remaining idle peer) is reachable.
    const WORKERS: usize = 6; // worker w serves CLASSES[w % 2]
    let class_of = |w: usize| CLASSES[w % CLASSES.len()];
    check(
        "speculative jobqueue",
        Config { cases: 64, seed: 173 },
        |r| {
            (0..r.range_usize(30, 90))
                .map(|_| (r.range_usize(0, 5) as u8, r.next_u64()))
                .collect::<Vec<_>>()
        },
        |ops| {
            let mut q = JobQueue::new();
            let mut primary: BTreeMap<u64, usize> = BTreeMap::new();
            let mut spec: BTreeMap<u64, usize> = BTreeMap::new();
            let mut completions: BTreeMap<u64, &str> = BTreeMap::new();
            let mut submitted = 0usize;
            let (mut dead_stalls, mut requeued_total) = (0usize, 0usize);
            let busy_model = |primary: &BTreeMap<u64, usize>, spec: &BTreeMap<u64, usize>, w: usize| {
                primary.values().any(|&p| p == w) || spec.values().any(|&s| s == w)
            };
            for (op, salt) in ops {
                let salt = *salt as usize;
                match op {
                    0 => {
                        q.submit(CLASSES[salt % CLASSES.len()], "f", vec![salt % 7], 10);
                        submitted += 1;
                    }
                    1 => {
                        let w = salt % WORKERS;
                        if busy_model(&primary, &spec, w) {
                            prop_assert!(
                                q.assign(w, class_of(w)).is_none(),
                                "worker {w} assigned while holding a job"
                            );
                        } else if let Some(j) = q.assign(w, class_of(w)) {
                            prop_assert!(j.device == class_of(w), "cross-class assignment");
                            primary.insert(j.id, w);
                        }
                    }
                    2 => {
                        // Speculative re-issue: duplicate a random
                        // in-flight job to an idle same-class peer.  A
                        // second speculation *replaces* the first (the
                        // leader re-speculates when the first
                        // speculation stalls too), freeing the old
                        // assignee.
                        if primary.is_empty() {
                            continue;
                        }
                        let id = *primary.keys().nth(salt % primary.len()).unwrap();
                        let holder = primary[&id];
                        let class = class_of(holder);
                        let idle: Vec<usize> = (0..WORKERS)
                            .filter(|&w| {
                                class_of(w) == class
                                    && w != holder
                                    && !busy_model(&primary, &spec, w)
                            })
                            .collect();
                        let Some(&w) = idle.get(salt / 7 % idle.len().max(1)) else {
                            continue;
                        };
                        let j = q.speculate(id, w, class);
                        prop_assert!(j.is_some(), "eligible speculation refused for job {id}");
                        spec.insert(id, w); // replaces (and frees) any prior assignee
                    }
                    3 => {
                        // First result wins: complete by whichever
                        // runner the schedule favours; the rival's
                        // duplicate must then be rejected.
                        if primary.is_empty() {
                            continue;
                        }
                        let id = *primary.keys().nth(salt % primary.len()).unwrap();
                        let holder = primary.remove(&id).unwrap();
                        let rival = spec.remove(&id);
                        let (winner, loser) = match rival {
                            Some(s) if salt % 2 == 0 => (s, Some(holder)),
                            Some(s) => (holder, Some(s)),
                            None => (holder, None),
                        };
                        prop_assert!(q.complete(id, winner), "winning completion rejected");
                        prop_assert!(
                            completions.insert(id, class_of(winner)).is_none(),
                            "job {id} completed twice"
                        );
                        if let Some(l) = loser {
                            prop_assert!(
                                !q.complete(id, l),
                                "duplicate completion from the rival runner accepted"
                            );
                        }
                    }
                    4 => {
                        // Stall-kill the primary runner.  With a
                        // speculative runner in flight the job is
                        // promoted, not re-queued; without one it goes
                        // back to the queue.
                        if primary.is_empty() {
                            continue;
                        }
                        let id = *primary.keys().nth(salt % primary.len()).unwrap();
                        let holder = primary.remove(&id).unwrap();
                        let n = q.requeue_worker(holder);
                        match spec.remove(&id) {
                            Some(s) => {
                                prop_assert!(n == 0, "promotion counted as a requeue");
                                primary.insert(id, s);
                            }
                            None => {
                                prop_assert!(n == 1, "stalled job not re-queued ({n})");
                                dead_stalls += 1;
                                requeued_total += n;
                            }
                        }
                    }
                    _ => {
                        // Stall-kill the speculative runner: the job
                        // stays with its primary, nothing re-queues.
                        if spec.is_empty() {
                            continue;
                        }
                        let id = *spec.keys().nth(salt % spec.len()).unwrap();
                        let s = spec.remove(&id).unwrap();
                        prop_assert!(
                            q.requeue_worker(s) == 0,
                            "killing a speculative runner re-queued a job"
                        );
                    }
                }
            }
            prop_assert!(
                requeued_total == dead_stalls,
                "{requeued_total} requeues for {dead_stalls} unspeculated stalls"
            );
            // Drain: finish the in-flight holds, then pump the idle
            // fleet until the queue is empty.
            for (id, w) in std::mem::take(&mut primary) {
                prop_assert!(q.complete(id, w), "drain completion rejected");
                prop_assert!(completions.insert(id, class_of(w)).is_none(), "completed twice");
            }
            let mut guard = 0;
            while q.pending() > 0 {
                guard += 1;
                prop_assert!(guard < 100_000, "drain did not terminate");
                for w in 0..WORKERS {
                    if let Some(j) = q.assign(w, class_of(w)) {
                        prop_assert!(j.device == class_of(w), "cross-class drain assignment");
                        prop_assert!(q.complete(j.id, w), "drain completion rejected");
                        prop_assert!(
                            completions.insert(j.id, class_of(w)).is_none(),
                            "completed twice"
                        );
                    }
                }
            }
            prop_assert!(
                completions.len() == submitted,
                "{} completions for {submitted} submitted jobs",
                completions.len()
            );
            prop_assert!(q.done() == submitted, "queue ledger disagrees");
            // Exactly-once *per class*: every completion — primary or
            // speculative — happened on a worker of the job's own class.
            for (id, class) in &completions {
                prop_assert!(
                    q.get(*id).map(|j| j.device.as_str()) == Some(*class),
                    "job {id} completed on foreign class {class}"
                );
            }
            Ok(())
        },
    );
}

/// A fan-out experiment with one deliberately panicking subtask, for
/// injecting failure into a real suite run.
struct SickFan;

impl Experiment for SickFan {
    fn id(&self) -> &'static str {
        "sickfan"
    }
    fn description(&self) -> &'static str {
        "fan-out with one panicking subtask"
    }
    fn subtasks(&self, _cfg: &ExpConfig) -> Vec<Subtask> {
        ["ok-a", "boom", "ok-b"]
            .into_iter()
            .map(|l| {
                Subtask::new(l, move |scfg: &ExpConfig| {
                    if l == "boom" {
                        panic!("injected subtask panic");
                    }
                    scfg.seed
                })
            })
            .collect()
    }
    fn merge(&self, cfg: &ExpConfig, parts: Vec<SubtaskOutput>) -> ExpReport {
        let mut r = ExpReport::new(self.id(), "sick fan", cfg, &[]);
        r.metric("parts", parts.len() as f64);
        r
    }
}

#[test]
fn prop_subtask_fanout_reports_byte_identical_across_thread_counts() {
    // The tentpole determinism contract: for a fixed suite seed, the
    // fanned-out experiments (fig8's device × family grid, fig13's
    // budget sweep) serialize byte-identically at 1, 2 and 8 threads —
    // including with an injected subtask panic in the same suite, which
    // must fail only its own experiment, with a byte-stable message.
    let mk = || -> Vec<Box<dyn Experiment>> {
        vec![by_id("fig8").unwrap(), by_id("fig13").unwrap(), Box::new(SickFan)]
    };
    let suites: Vec<_> = [1usize, 2, 8].iter().map(|&t| Runner::new(t).run(mk(), true, 11)).collect();

    let jsons: Vec<Vec<String>> = suites
        .iter()
        .map(|s| s.reports.iter().map(|r| r.to_json().to_string()).collect())
        .collect();
    for (i, run) in jsons.iter().enumerate().skip(1) {
        assert_eq!(jsons[0].len(), run.len());
        for (a, b) in jsons[0].iter().zip(run) {
            assert_eq!(a, b, "suite JSON diverged between 1 thread and run #{i}");
        }
    }

    let one = &suites[0];
    assert!(one.reports[0].error.is_none(), "fig8 failed: {:?}", one.reports[0].error);
    assert!(one.reports[1].error.is_none(), "fig13 failed: {:?}", one.reports[1].error);
    let err = one.reports[2].error.as_deref().expect("sickfan must fail");
    assert!(
        err.contains("subtask 'boom'") && err.contains("injected subtask panic"),
        "unexpected failure message: {err}"
    );
}

#[test]
fn prop_sparse_gpscale_report_byte_identical_across_thread_counts() {
    // PR 9 determinism contract (satellite): the sparse-backend arms of
    // the gpscale experiment — inducing selection, Nyström factors, the
    // coord-descent over the sparse NLML — serialize byte-identically at
    // 1, 2 and 8 suite threads, exactly like every exact-path experiment.
    let suites: Vec<_> = [1usize, 2, 8]
        .iter()
        .map(|&t| Runner::new(t).run(vec![by_id("gpscale").unwrap()], true, 11))
        .collect();
    let jsons: Vec<String> =
        suites.iter().map(|s| s.reports[0].to_json().to_string()).collect();
    assert!(suites[0].reports[0].error.is_none(), "{:?}", suites[0].reports[0].error);
    assert_eq!(jsons[0], jsons[1], "gpscale diverged between 1 and 2 threads");
    assert_eq!(jsons[0], jsons[2], "gpscale diverged between 1 and 8 threads");
}

#[test]
fn prop_sparse_fit_deterministic_over_random_surfaces() {
    // Over random training sets: a sparse fit is a pure function of
    // (xs, ys, m) — byte-equal serialized model and bit-equal posterior
    // whether the workspace is fresh or dirty from unrelated fits.
    use thor::gp::{FitWorkspace, GpBackend, GpModel, KernelKind};
    check(
        "sparse fit determinism",
        Config { cases: 25, seed: 97 },
        |r| {
            let n = r.range_usize(12, 40);
            let m = r.range_usize(3, 10);
            let xs: Vec<Vec<f64>> = (0..n).map(|_| vec![r.f64(), r.f64()]).collect();
            let ys: Vec<f64> =
                xs.iter().map(|x| (2.0 + x[0] + 3.0 * x[1] * x[1]).ln() + 0.01 * r.f64()).collect();
            (xs, ys, m)
        },
        |(xs, ys, m)| {
            let backend = GpBackend::Sparse { m: *m };
            let mut fresh = FitWorkspace::new();
            let a = GpModel::fit_b(&mut fresh, KernelKind::Matern52, xs.clone(), ys, backend);
            // Dirty workspace: an unrelated exact fit first.
            let mut dirty = FitWorkspace::new();
            let other: Vec<Vec<f64>> = (0..7).map(|i| vec![i as f64 / 6.0, 0.3]).collect();
            let oys: Vec<f64> = other.iter().map(|x| 1.0 + x[0]).collect();
            let _ = GpModel::fit_with(&mut dirty, KernelKind::Matern52, other, &oys);
            let b = GpModel::fit_b(&mut dirty, KernelKind::Matern52, xs.clone(), ys, backend);
            match (a, b) {
                (Some(a), Some(b)) => {
                    prop_assert!(
                        a.to_json().to_string() == b.to_json().to_string(),
                        "serialized models diverged (n={}, m={m})",
                        xs.len()
                    );
                    for i in 0..8 {
                        let q = vec![i as f64 / 7.0, 1.0 - i as f64 / 7.0];
                        let (ma, va) = a.predict(&q);
                        let (mb, vb) = b.predict(&q);
                        prop_assert!(
                            ma.to_bits() == mb.to_bits() && va.to_bits() == vb.to_bits(),
                            "posterior diverged at {q:?}"
                        );
                    }
                    Ok(())
                }
                (None, None) => Ok(()),
                _ => {
                    prop_assert!(false, "one fit succeeded, the other failed");
                    Ok(())
                }
            }
        },
    );
}

#[test]
fn prop_conv_kind_hash_eq_consistent() {
    // FamilyKey dedup relies on LayerKind Eq/Hash agreement.
    check(
        "layerkind eq-hash",
        Config { cases: 100, seed: 151 },
        |r| {
            let mk = |r: &mut Pcg64| LayerKind::Conv2d {
                kernel: r.range_usize(1, 7),
                stride: r.range_usize(1, 2),
                padded: r.bool(0.5),
            };
            (mk(r), mk(r))
        },
        |(a, b)| {
            use std::collections::hash_map::DefaultHasher;
            use std::hash::{Hash, Hasher};
            let h = |k: &LayerKind| {
                let mut s = DefaultHasher::new();
                k.hash(&mut s);
                s.finish()
            };
            if a == b {
                prop_assert!(h(a) == h(b), "eq but hash differs");
            }
            Ok(())
        },
    );
}
