//! Integration tests across modules: THOR pipeline × simulator ×
//! baselines × coordinator (in-process TCP) × runtime (PJRT artifacts —
//! skipped gracefully when artifacts/ have not been built).

use thor::coordinator::{DeviceWorker, FleetServer};
use thor::exp::measured_energy;
use thor::gp::{GpModel, KernelKind};
use thor::model::{sampler, zoo};
use thor::runtime::{GpExecutor, Runtime, TrainStep};
use thor::simdevice::{devices, Device};
use thor::thor::{estimator, Thor, ThorConfig};
use thor::trainer::{train, GenderLikeData};
use thor::util::stats::mape;

fn artifacts_available() -> bool {
    Runtime::default_dir().join("manifest.json").exists()
}

#[test]
fn thor_full_pipeline_beats_flops_on_fixed_clock_device() {
    // Miniature Fig 8 row (xavier × cnn5) — the headline claim.
    let mut dev = Device::new(devices::xavier(), 42);
    let reference = zoo::cnn5(&[32, 64, 128, 256], 28, 10);
    let mut thor = Thor::new(ThorConfig { iterations: 200, ..ThorConfig::default() });
    thor.profile_local(&mut dev, &reference);

    let train_models = sampler::sample_n(sampler::Family::Cnn5, 12, 7, 10);
    let lr = thor::baselines::flops_lr::FlopsLr::fit_on_device(&mut dev, &train_models, 100);

    let test: Vec<_> = sampler::sample_n(sampler::Family::Cnn5, 12, 8, 10);
    let (mut actual, mut p_lr, mut p_th) = (vec![], vec![], vec![]);
    for g in &test {
        actual.push(measured_energy(&mut dev, g, 200, 2));
        p_lr.push(lr.predict(g));
        p_th.push(thor.estimate("xavier", g).unwrap().energy_per_iter);
    }
    let m_th = mape(&actual, &p_th);
    let m_lr = mape(&actual, &p_lr);
    assert!(m_th < 25.0, "THOR MAPE {m_th}%");
    assert!(m_th < m_lr * 1.2, "THOR {m_th}% should not lose to FLOPs-LR {m_lr}%");
}

#[test]
fn store_roundtrip_preserves_estimates() {
    let mut dev = Device::new(devices::tx2(), 11);
    let reference = zoo::cnn5(&[16, 32, 64, 128], 16, 10);
    let mut thor = Thor::new(ThorConfig::quick());
    thor.profile_local(&mut dev, &reference);
    let path = std::env::temp_dir().join("thor_integration_store.json");
    thor.store.save(&path).unwrap();
    let loaded = thor::thor::store::GpStore::load(&path).unwrap().unwrap();
    let g = zoo::cnn5(&[8, 16, 32, 64], 16, 10);
    let a = thor.estimate("tx2", &g).unwrap().energy_per_iter;
    let b = estimator::estimate(&loaded, "tx2", &g).unwrap().energy_per_iter;
    assert!((a - b).abs() < 1e-9 * a.max(1.0), "{a} vs {b}");
    std::fs::remove_file(path).ok();
}

#[test]
fn coordinator_fleet_matches_local_profiling_quality() {
    // Leader + 2 workers over loopback TCP; resulting store estimates
    // unseen variants about as well as local profiling does.
    let reference = zoo::cnn5(&[16, 32, 64, 128], 28, 10);
    let addr = "127.0.0.1:7733";
    let mut handles = Vec::new();
    for w in 0..2u64 {
        let reference = reference.clone();
        handles.push(std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(150 + 40 * w));
            let mut worker = DeviceWorker::new(Device::new(devices::xavier(), 100 + w), &reference);
            worker.run(addr)
        }));
    }
    let server = FleetServer::new(ThorConfig { iterations: 150, ..ThorConfig::default() });
    let store = server.run(addr, &reference, 2).unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    assert!(store.len() >= 5, "fleet store has {} families", store.len());

    let mut dev = Device::new(devices::xavier(), 5);
    let (mut actual, mut est) = (vec![], vec![]);
    for ch in [[8usize, 16, 32, 64], [4, 20, 50, 90], [12, 6, 3, 2]] {
        let g = zoo::cnn5(&ch, 28, 10);
        actual.push(measured_energy(&mut dev, &g, 150, 2));
        est.push(estimator::estimate(&store, "xavier", &g).unwrap().energy_per_iter);
    }
    let m = mape(&actual, &est);
    assert!(m < 35.0, "fleet store MAPE {m}%");
}

#[test]
fn artifact_gp_matches_native_gp() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut rt = Runtime::open(&Runtime::default_dir()).unwrap();
    for dim in [1usize, 2] {
        // Well-separated inducing sets, like real profiling data (dense
        // near-duplicate points make K ill-conditioned, which the f32
        // artifact path cannot invert as accurately as the f64 native
        // path — THOR's acquisition never produces such sets).
        let n = if dim == 1 { 16 } else { 25 };
        let xs: Vec<Vec<f64>> = (0..n)
            .map(|i| {
                (0..dim)
                    .map(|d| if dim == 1 { i as f64 / (n - 1) as f64 } else if d == 0 { (i % 5) as f64 / 4.0 } else { (i / 5) as f64 / 4.0 })
                    .collect()
            })
            .collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 + x.iter().sum::<f64>().sin()).collect();
        let gp = GpModel::fit(KernelKind::Matern52, xs, &ys).unwrap();
        let queries: Vec<Vec<f64>> = (0..300)
            .map(|i| (0..dim).map(|d| ((i + d * 7) % 100) as f64 / 99.0).collect())
            .collect();
        let (mn, vn) = gp.predict_batch(&queries);
        let (ma, va) = GpExecutor::posterior(&mut rt, &gp.export(), &queries).unwrap();
        for i in 0..queries.len() {
            assert!((mn[i] - ma[i]).abs() < 2e-3, "dim {dim} q{i}: {} vs {}", mn[i], ma[i]);
            // Variance agreement is limited by f32 cancellation of
            // σ² − k*ᵀK⁻¹k* when the fitted noise is tiny (K condition ≈
            // σ²/σ_n²); acquisition runs on the f64 native path, so the
            // artifact only needs variance to the σ²-scale tolerance.
            let var_scale = gp.hyper.variance * gp.y_scale * gp.y_scale;
            assert!(
                (vn[i] - va[i]).abs() < 1.5e-2 * var_scale.max(1e-6) + 0.1 * vn[i].abs(),
                "dim {dim} q{i}: var {} vs {} (scale {var_scale})", vn[i], va[i]
            );
        }
    }
}

#[test]
fn artifact_training_learns() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut rt = Runtime::open(&Runtime::default_dir()).unwrap();
    let mut ts = TrainStep::new(3);
    let mut data = GenderLikeData::new(5, 0.7);
    let report = train(&mut rt, &mut ts, &mut data, 150, 0.08, 50).unwrap();
    let eval = report.eval.unwrap();
    assert!(eval.acc > 0.75, "acc {}", eval.acc);
    let first = report.losses.first().unwrap().1;
    let last = report.losses.last().unwrap().1;
    assert!(last < first * 0.8, "loss {first} -> {last}");
}

#[test]
fn artifact_pruned_training_freezes_masked_channels() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut rt = Runtime::open(&Runtime::default_dir()).unwrap();
    let mut ts = TrainStep::with_pruned(3, 4, 8);
    let w2_before = ts.params.w2.clone();
    let mut data = GenderLikeData::new(5, 0.7);
    train(&mut rt, &mut ts, &mut data, 20, 0.1, 10).unwrap();
    // masked conv2 output channels (>= 8) must be bit-identical
    let c1 = thor::runtime::trainstep::C1;
    let c2 = thor::runtime::trainstep::C2;
    for k in 0..9 * c1 {
        for ch in 8..c2 {
            let idx = k * c2 + ch;
            assert_eq!(ts.params.w2[idx], w2_before[idx], "masked weight moved at {idx}");
        }
    }
}

#[test]
fn neuralpower_overestimates_fig2_shape() {
    let g = zoo::cnn5(&[16, 32, 64, 128], 16, 10);
    let mut dev = Device::new(devices::xavier(), 2);
    let est = thor::baselines::neuralpower::estimate(&mut dev, &g, 60);
    let observed = measured_energy(&mut dev, &g, 60, 2);
    assert!(est > observed, "NeuralPower-style {est} should exceed observed {observed}");
}
