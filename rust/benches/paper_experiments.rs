//! `cargo bench --bench paper_experiments` regenerates every table and
//! figure of the paper's evaluation through the experiment registry
//! (`thor::exp::registry`), fanned across threads by the deterministic
//! runner.  Honors:
//!   THOR_BENCH_QUICK=1    — reduced sample counts (default here: quick;
//!                           set =0 for full paper scale)
//!   THOR_BENCH_ONLY=fig8  — run a single experiment (`tab1` → fig8)
//!   THOR_BENCH_SEED=2025  — suite seed
//!   THOR_BENCH_THREADS=4  — worker threads (default: all cores, min 2)

use thor::exp::{registry, Experiment as _, Runner};

fn main() {
    let quick = std::env::var("THOR_BENCH_QUICK").map(|v| v != "0").unwrap_or(true);
    let only = std::env::var("THOR_BENCH_ONLY").ok();
    let seed = std::env::var("THOR_BENCH_SEED").ok().and_then(|v| v.parse().ok()).unwrap_or(2025);
    let threads: usize =
        std::env::var("THOR_BENCH_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(0);

    let exps: Vec<_> = registry::registry()
        .into_iter()
        .filter(|e| match only.as_deref() {
            None => true,
            Some("tab1") => e.id() == "fig8",
            Some(o) => e.id() == o,
        })
        .collect();
    if exps.is_empty() {
        eprintln!(
            "THOR_BENCH_ONLY={:?} matches no experiment; registry: {:?}",
            only,
            registry::ids()
        );
        std::process::exit(2);
    }

    let runner = Runner::from_arg(threads);
    let n = exps.len();
    let suite = runner.run(exps, quick, seed);

    println!(
        "# THOR paper experiments (quick={quick}, seed={seed}, {} threads)\n",
        suite.threads_used
    );
    print!("{}", suite.render());
    eprintln!("ran {n} experiment(s) in {:.1}s", suite.wall_seconds);
    if suite.eprint_failures() > 0 {
        std::process::exit(1);
    }
}
