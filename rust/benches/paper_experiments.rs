//! `cargo bench --bench paper_experiments` regenerates every table and
//! figure of the paper's evaluation (DESIGN.md §6 maps each to its
//! module).  Honors:
//!   THOR_BENCH_QUICK=1   — reduced sample counts (default here: quick,
//!                          since `cargo bench` runs everything serially
//!                          on one core; set =0 for full paper scale)
//!   THOR_BENCH_ONLY=fig8 — run a single experiment

use thor::exp::{self, ExpConfig};

fn main() {
    let quick = std::env::var("THOR_BENCH_QUICK").map(|v| v != "0").unwrap_or(true);
    let only = std::env::var("THOR_BENCH_ONLY").ok();
    let cfg = ExpConfig::new(quick, 2025);
    let run = |name: &str| only.as_deref().map_or(true, |o| o == name);

    println!("# THOR paper experiments (quick={quick})\n");

    if run("fig2") {
        println!("## Fig 2 — NeuralPower-style per-stage estimation overestimates\n{}", exp::fig2::run(&cfg));
    }
    if run("fig4") {
        println!("## Fig 4 — GP + max-variance acquisition steps\n{}", exp::fig4::run(&cfg));
    }
    if run("fig5") {
        println!("## Fig 5 — FC energy vs channel (non-linear)\n{}", exp::fig5::run(&cfg));
    }
    if run("fig6") {
        println!("## Fig 6 — time ↔ energy correlation\n{}", exp::fig6::run(&cfg));
    }
    if run("fig7") {
        println!("## Fig 7 — estimated vs actual (FLOPs vs THOR)\n{}", exp::fig7::run(&cfg));
    }
    if run("fig8") || run("tab1") {
        let (f8, t1) = exp::fig8::run(&cfg);
        println!("## Fig 8 — end-to-end MAPE across devices\n{f8}");
        println!("## Table 1 — profiling + fitting time cost (s)\n{t1}");
    }
    if run("fig9") {
        println!("## Fig 9 — Transformer estimation\n{}", exp::fig9::run(&cfg));
    }
    if run("fig10") {
        println!("## Fig 10 — ResNet error CDF\n{}", exp::fig10::run(&cfg));
    }
    if run("fig11") {
        println!("## Fig 11 — conv2d energy surfaces\n{}", exp::fig11::run(&cfg));
    }
    if run("fig12") {
        println!("## Fig 12 — estimation − observation\n{}", exp::fig12::run(&cfg));
    }
    if run("a14") {
        println!("## Fig A14 — profiled points vs MAPE\n{}", exp::a14::run(&cfg));
    }
    if run("a15") {
        println!("## Fig A15 — GP kernel ablation\n{}", exp::a15::run(&cfg));
    }
    if run("a16") {
        println!("## Fig A16 — energy vs profiling iterations\n{}", exp::a16::run(&cfg));
    }
    println!("# (Fig 13 — pruning case study — runs as examples/energy_aware_pruning)");
}
