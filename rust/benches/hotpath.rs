//! `cargo bench --bench hotpath` — §Perf microbenchmarks for the three
//! optimization targets (EXPERIMENTS.md §Perf records before/after):
//!
//!   L3  GP predict (native) / estimate() / simulator trace execution
//!   L2+L1  artifact-backed batched GP posterior through PJRT
//!          (skipped with a notice if artifacts/ are missing)

use std::time::Duration;

use thor::gp::{GpModel, KernelKind};
use thor::model::zoo;
use thor::runtime::{GpExecutor, Runtime};
use thor::simdevice::{devices, Device};
use thor::thor::{Thor, ThorConfig};
use thor::util::bench::{bench, black_box};
use thor::util::table;
use thor::workload::{fusion::fuse, lower::lower};

fn main() {
    let budget = Duration::from_millis(
        std::env::var("THOR_BENCH_MS").ok().and_then(|v| v.parse().ok()).unwrap_or(800),
    );
    let mut rows = Vec::new();

    // --- L3: native GP predict (the per-layer estimation primitive) -------
    let xs: Vec<Vec<f64>> = (0..48).map(|i| vec![(i % 8) as f64 / 7.0, (i / 8) as f64 / 5.0]).collect();
    let ys: Vec<f64> = xs.iter().map(|x| (1.0 + x[0] + x[1]).ln()).collect();
    let gp = GpModel::fit(KernelKind::Matern52, xs, &ys).unwrap();
    let queries: Vec<Vec<f64>> = (0..256).map(|i| vec![(i % 16) as f64 / 15.0, (i / 16) as f64 / 15.0]).collect();
    rows.push(
        bench("L3 gp.predict_batch(256q, n=48)", budget, || {
            black_box(gp.predict_batch(black_box(&queries)));
        })
        .row(),
    );

    // --- L3: full-model estimate() -----------------------------------------
    let mut dev = Device::new(devices::xavier(), 1);
    let mut thor = Thor::new(ThorConfig::quick());
    let reference = zoo::cnn5(&[32, 64, 128, 256], 16, 10);
    thor.profile(&mut dev, &reference);
    let target = zoo::cnn5(&[16, 32, 64, 128], 16, 10);
    rows.push(
        bench("L3 thor.estimate(cnn5)", budget, || {
            black_box(thor.estimate("xavier", black_box(&target)).unwrap());
        })
        .row(),
    );

    // --- L3: simulator trace execution (profiling inner loop) --------------
    let trace = fuse(&lower(&target));
    rows.push(
        bench("L3 device.run(trace, 10 iters)", budget, || {
            black_box(dev.run(black_box(&trace), 10));
        })
        .row(),
    );

    // --- L3: lowering + fusion ----------------------------------------------
    rows.push(
        bench("L3 lower+fuse(cnn5)", budget, || {
            black_box(fuse(&lower(black_box(&target))));
        })
        .row(),
    );

    // --- L1+L2: artifact GP posterior through PJRT --------------------------
    match Runtime::open(&Runtime::default_dir()) {
        Ok(mut rt) => {
            let export = gp.export();
            // warm the executable cache before timing
            let _ = GpExecutor::posterior(&mut rt, &export, &queries);
            rows.push(
                bench("L1+L2 artifact gp_posterior (256q)", budget, || {
                    black_box(GpExecutor::posterior(&mut rt, &export, black_box(&queries)).unwrap());
                })
                .row(),
            );
        }
        Err(e) => println!("(skipping artifact benches: {e})"),
    }

    println!(
        "{}",
        table::render(&["benchmark", "iters", "mean", "p50", "p95", "min"], &rows)
    );
}
