//! `cargo bench --bench hotpath` — §Perf microbenchmarks for the
//! optimization targets (EXPERIMENTS.md §Perf records before/after):
//!
//!   L3  GP fit engine: `GpModel::fit`, `fit_family`, batched predict,
//!       and the PR-9 fit-time-vs-n sweep (exact vs sparse m=64 at
//!       n ∈ {32, 128, 512, 2048}; `THOR_BENCH_EXACT_CAP` bounds the
//!       cubic exact arms)
//!   L3  estimate() (cnn5 + resnet56 batched-family path) / simulator
//!       trace execution
//!   L2+L1  artifact-backed batched GP posterior through PJRT
//!          (skipped with a notice if artifacts/ are missing)
//!
//! `-- --json BENCH_<pr>.json` writes the structured results for the
//! perf trajectory (schema: {"schema_version":1,"benches":[...]}).

use std::time::Duration;

use thor::gp::{GpModel, KernelKind};
use thor::model::zoo;
use thor::runtime::{GpExecutor, Runtime};
use thor::simdevice::{devices, Device};
use thor::thor::fit::{fit_family, FitConfig};
use thor::thor::{Thor, ThorConfig};
use thor::util::bench::{bench, black_box, BenchResult};
use thor::util::cli::{parse, Spec};
use thor::util::json::Json;
use thor::util::table;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let specs = [
        Spec { name: "json", takes_value: true, help: "write structured results to this path" },
        // `cargo bench` appends --bench to harness=false binaries; accept
        // and ignore it so the strict parser doesn't reject every run.
        Spec { name: "bench", takes_value: false, help: "(ignored; passed by cargo bench)" },
        Spec { name: "help", takes_value: false, help: "print usage" },
    ];
    let args = parse(&argv, &specs).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    if args.has("help") {
        println!("{}", thor::util::cli::usage("cargo bench --bench hotpath --", &specs));
        return;
    }
    let budget = Duration::from_millis(
        std::env::var("THOR_BENCH_MS").ok().and_then(|v| v.parse().ok()).unwrap_or(800),
    );
    let mut results: Vec<BenchResult> = Vec::new();

    // --- L3: GP hyper-parameter fit (the fit-engine tentpole) ---------------
    // Shape matches a 2-D hidden-family fit at full budget: the multi-start
    // NLML search dominates, so this is where the DistGram + workspace
    // engine must show its ≥5× (EXPERIMENTS.md §Perf).
    let fit_xs: Vec<Vec<f64>> = (0..24)
        .map(|i| vec![((i * 7) % 24) as f64 / 23.0, ((i * 5) % 24) as f64 / 23.0])
        .collect();
    let fit_ys: Vec<f64> =
        fit_xs.iter().map(|x| (1.0 + 2.0 * x[0] + x[1] * x[1]).ln()).collect();
    results.push(bench("L3 GpModel::fit(n=24, 2d)", budget, || {
        black_box(GpModel::fit(
            KernelKind::Matern52,
            black_box(fit_xs.clone()),
            black_box(&fit_ys),
        ));
    }));

    // --- L3: fit-time-vs-n sweep, exact vs sparse (PR 9) --------------------
    // The sparse backend's whole case: exact fitting is O(n³) per NLML
    // evaluation, sparse is O(n·m²) at fixed m = 64 — the sweep makes the
    // crossover visible in BENCH_pr9.json.  Exact arms above
    // THOR_BENCH_EXACT_CAP (default 512) are skipped with a notice so the
    // sweep stays tractable on slow machines; the sparse arm always runs
    // (at n ≤ m it resolves exact by the `m < n` rule, so the n=32 pair
    // doubles as a dispatch-overhead check).
    {
        use thor::gp::{FitWorkspace, GpBackend};
        let exact_cap: usize = std::env::var("THOR_BENCH_EXACT_CAP")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(512);
        for n in [32usize, 128, 512, 2048] {
            let xs: Vec<Vec<f64>> = (0..n)
                .map(|i| vec![((i * 7) % n) as f64 / (n - 1) as f64, ((i * 5) % n) as f64 / (n - 1) as f64])
                .collect();
            let ys: Vec<f64> =
                xs.iter().map(|x| (1.0 + 2.0 * x[0] + x[1] * x[1]).ln()).collect();
            if n <= exact_cap {
                results.push(bench(&format!("L3 fit-vs-n exact (n={n})"), budget, || {
                    let mut ws = FitWorkspace::new();
                    black_box(GpModel::fit_b(
                        &mut ws,
                        KernelKind::Matern52,
                        black_box(xs.clone()),
                        black_box(&ys),
                        GpBackend::Exact,
                    ));
                }));
            } else {
                println!("(skipping exact fit at n={n}: above THOR_BENCH_EXACT_CAP={exact_cap})");
            }
            results.push(bench(&format!("L3 fit-vs-n sparse m=64 (n={n})"), budget, || {
                let mut ws = FitWorkspace::new();
                black_box(GpModel::fit_b(
                    &mut ws,
                    KernelKind::Matern52,
                    black_box(xs.clone()),
                    black_box(&ys),
                    GpBackend::Sparse { m: 64 },
                ));
            }));
        }
    }

    // --- L3: full acquisition loop (warm refits after one full fit) ---------
    let fcfg = FitConfig { max_points: 16, grid_n: 33, threshold_frac: 0.0, ..Default::default() };
    results.push(bench("L3 fit_family(1d, 16 pts)", budget, || {
        black_box(fit_family(
            |p| (100.0 + 60.0 * p[0] + 10.0 * (6.0 * p[0]).sin(), 0.1),
            1,
            black_box(&fcfg),
        ));
    }));

    // --- L3: native GP predict (the per-layer estimation primitive) -------
    let xs: Vec<Vec<f64>> = (0..48).map(|i| vec![(i % 8) as f64 / 7.0, (i / 8) as f64 / 5.0]).collect();
    let ys: Vec<f64> = xs.iter().map(|x| (1.0 + x[0] + x[1]).ln()).collect();
    let gp = GpModel::fit(KernelKind::Matern52, xs, &ys).unwrap();
    let queries: Vec<Vec<f64>> = (0..256).map(|i| vec![(i % 16) as f64 / 15.0, (i / 16) as f64 / 15.0]).collect();
    results.push(bench("L3 gp.predict_batch(256q, n=48)", budget, || {
        black_box(gp.predict_batch(black_box(&queries)));
    }));

    // --- L3: full-model estimate() -----------------------------------------
    let mut dev = Device::new(devices::xavier(), 1);
    let mut thor = Thor::new(ThorConfig::quick());
    let reference = zoo::cnn5(&[32, 64, 128, 256], 16, 10);
    thor.profile_local(&mut dev, &reference);
    let target = zoo::cnn5(&[16, 32, 64, 128], 16, 10);
    results.push(bench("L3 thor.estimate(cnn5)", budget, || {
        black_box(thor.estimate("xavier", black_box(&target)).unwrap());
    }));

    // --- L3: estimate() on a deep model (batched-family hot path) -----------
    // ResNet-56: 55 conv groups collapsing to a handful of families — the
    // per-family predict_batch grouping is the whole point here.
    let resnet_ref = zoo::resnet(56, 16, 10);
    let mut rdev = Device::new(devices::xavier(), 2);
    let mut rthor = Thor::new(ThorConfig::quick());
    rthor.profile_local(&mut rdev, &resnet_ref);
    results.push(bench("L3 thor.estimate(resnet56)", budget, || {
        black_box(rthor.estimate("xavier", black_box(&resnet_ref)).unwrap());
    }));

    // --- L3: simulator trace execution (profiling inner loop) --------------
    use thor::workload::{fusion::fuse, lower::lower};
    let trace = fuse(&lower(&target));
    results.push(bench("L3 device.run(trace, 10 iters)", budget, || {
        black_box(dev.run(black_box(&trace), 10));
    }));

    // --- L3: lowering + fusion ----------------------------------------------
    results.push(bench("L3 lower+fuse(cnn5)", budget, || {
        black_box(fuse(&lower(black_box(&target))));
    }));

    // --- L1+L2: artifact GP posterior through PJRT --------------------------
    match Runtime::open(&Runtime::default_dir()) {
        Ok(mut rt) => {
            let export = gp.export();
            // warm the executable cache before timing
            let _ = GpExecutor::posterior(&mut rt, &export, &queries);
            results.push(bench("L1+L2 artifact gp_posterior (256q)", budget, || {
                black_box(GpExecutor::posterior(&mut rt, &export, black_box(&queries)).unwrap());
            }));
        }
        Err(e) => println!("(skipping artifact benches: {e})"),
    }

    let rows: Vec<Vec<String>> = results.iter().map(|r| r.row()).collect();
    println!(
        "{}",
        table::render(&["benchmark", "iters", "mean", "p50", "p95", "min"], &rows)
    );

    if let Some(path) = args.get("json") {
        let j = Json::obj(vec![
            ("schema_version", Json::Num(1.0)),
            ("benches", Json::Arr(results.iter().map(|r| r.to_json()).collect())),
        ]);
        std::fs::write(path, j.to_string()).expect("write bench json");
        eprintln!("wrote {} benchmark(s) to {path}", results.len());
    }
}
